"""Deterministic synthetic token pipeline (shard-aware, restart-exact).

Production shape: an index-based source (step -> global batch) so any
worker can materialize its shard of any step without coordination — the
property that makes checkpoint/restart and elastic rescale exact. The
synthetic source is a keyed PRNG stream over (seed, step); a real corpus
source would swap `_materialize` for a tokenized-file gather with the same
index discipline.

Targets are next-token labels (shifted), with the final position masked.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    ignore_id: int = -1


class SyntheticTokenSource:
    """step -> {tokens, labels[, embeds]} with Zipf-ish token marginals."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig, dcfg: DataConfig = DataConfig()):
        self.arch = arch
        self.shape = shape
        self.dcfg = dcfg

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(np.uint64(self.dcfg.seed * 1_000_003 + step))
        b, l = self.shape.global_batch, self.shape.seq_len
        v = self.arch.vocab_size
        # Zipf-like marginal over vocab — exercises the sharded embedding
        # gather unevenly like real text.
        ranks = rng.zipf(1.3, size=(b, l + 1)).astype(np.int64)
        tokens = np.minimum(ranks - 1, v - 1).astype(np.int32)
        out = {
            "tokens": tokens[:, :l],
            "labels": tokens[:, 1 : l + 1],  # next-token targets, all valid
        }
        if self.arch.input_mode == "embeddings":
            out["embeds"] = rng.standard_normal((b, l, self.arch.d_model)).astype(np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def device_put_batch(batch: dict[str, np.ndarray], shardings: dict) -> dict[str, jax.Array]:
    return {k: jax.device_put(v, shardings[k]) if k in shardings else jnp.asarray(v)
            for k, v in batch.items()}
