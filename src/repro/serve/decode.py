"""Serving substrate: prefill + batched single-token decode steps.

``make_serve_step(model)`` returns the jit-able serve_step lowering target:
one new token per sequence against a KV cache of the shape's seq_len —
what decode_32k / long_500k lower. Sampling (greedy/temperature) runs on
the final sharded logits.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.transformer import Model

Array = jax.Array
PyTree = Any


def make_serve_step(model: Model, temperature: float = 0.0):
    """serve_step(params, caches, tokens, pos, key) -> (next_tokens, caches)."""

    def serve_step(params: PyTree, caches: PyTree, tokens: Array, pos: Array, key: Array):
        logits, caches = model.decode_step(params, caches, tokens, pos)
        last = logits[:, -1]
        if temperature > 0.0:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt.astype(jnp.int32)[:, None], caches

    return serve_step


def make_prefill(model: Model, cache_len: int):
    def prefill(params: PyTree, batch: PyTree):
        return model.prefill(params, batch, cache_len=cache_len)

    return prefill


def decode_input_specs(model: Model) -> dict[str, P]:
    ax = model.ax
    return {"tokens": P(ax.b, None), "pos": P(), "key": P()}


def generate(
    model: Model,
    params: PyTree,
    prompt: Array,  # (B, L) int32
    steps: int,
    cache_len: int | None = None,
    temperature: float = 0.0,
    key: Array | None = None,
    batch_extra: dict[str, Array] | None = None,
) -> Array:
    """Greedy/temperature generation loop (host-driven; each step jit'd)."""
    b, l = prompt.shape
    cache_len = cache_len or (l + steps)
    key = key if key is not None else jax.random.PRNGKey(0)
    batch = {"tokens": prompt}
    if batch_extra:
        batch.update(batch_extra)
    prefill = jax.jit(make_prefill(model, cache_len))
    step = jax.jit(make_serve_step(model, temperature))
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [tok]
    for i in range(steps - 1):
        key, sub = jax.random.split(key)
        tok, caches = step(params, caches, tok, jnp.asarray(l + i, jnp.int32), sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
