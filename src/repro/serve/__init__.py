from .decode import decode_input_specs, generate, make_prefill, make_serve_step  # noqa: F401
