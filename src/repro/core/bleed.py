"""Binary Bleed k-search, single rank & thread (paper Algorithm 1 + §III-C).

Two equivalent forms are provided:

  * ``binary_bleed_recursive`` — the paper's Algorithm 1, faithful recursive
    structure over index intervals ``[lo, hi)``: evaluate the midpoint, update
    the prune bounds on threshold crossings, recurse into both halves
    ("bleed") skipping any subtree whose k interval is fully pruned.

  * ``binary_bleed_worklist`` — iterative: walk the traversal-sorted k list
    (pre-order = same visit schedule as the recursion) and skip pruned
    entries. This is the form the multi-resource scheduler generalizes, and
    is restart-safe (the worklist position + bounds are the whole state).

Pruning state (the paper's ``k_min`` / ``k_max`` / ``ranks_seen``):

  * ``lo_bound``: highest k whose score crossed the *select* threshold T.
    Every unvisited k <= lo_bound is pruned — the objective
    ``k_opt = max{k : S(f(k)) ≥ T}`` cannot live there. (Vanilla)
  * ``hi_bound``: lowest k whose score crossed the *stop* threshold U.
    Every unvisited k >= hi_bound is pruned — domain knowledge says scores
    never recover past U. (Early Stop, §III-C)

A k is evaluated iff ``lo_bound < k < hi_bound``.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

from repro.obs import get_metrics, get_tracer

from .search_space import Mode, SearchResult, SearchSpace, VisitRecord
from .traversal import Order, traversal_sort

# evaluate(k) -> score. Long-running fits may additionally accept an
# ``should_abort`` kwarg (checked between fit chunks, §III-D) — the serial
# driver never aborts, the scheduler wires it to live prune state.
# Every driver also accepts an ``EvalPlane`` (anything with
# ``evaluate_batch``) in place of the scalar callable; scalar callables are
# wrapped in a ``ScalarEvalPlane`` adapter internally.
EvalFn = Callable[[int], float]


class BleedState:
    """Mutable prune state shared by all Binary Bleed drivers.

    Instrumented: records/skips/bound-merges flow to the process tracer and
    metrics registry (``repro.obs``) resolved at construction — a no-op
    ``NullTracer`` unless telemetry was installed (``ksearch --trace``).
    """

    __slots__ = (
        "space", "lo_bound", "hi_bound", "k_optimal", "visits", "_order_ctr",
        "_tracer", "_metrics",
    )

    def __init__(self, space: SearchSpace, tracer=None, metrics=None):
        self.space = space
        self.lo_bound = -math.inf  # ks <= lo_bound are pruned (select crossings)
        self.hi_bound = math.inf  # ks >= hi_bound are pruned (stop crossings)
        self.k_optimal: int | None = None
        self.visits: list[VisitRecord] = []
        self._order_ctr = 0
        self._tracer = tracer if tracer is not None else get_tracer()
        self._metrics = metrics if metrics is not None else get_metrics()
        self._metrics.set_gauge("ks_candidates", len(space.ks))

    # -- queries ---------------------------------------------------------------
    def should_visit(self, k: int) -> bool:
        return self.lo_bound < k < self.hi_bound

    def interval_alive(self, k_lo: int, k_hi: int) -> bool:
        """Does [k_lo, k_hi] (k values) intersect the open live interval?"""
        return k_hi > self.lo_bound and k_lo < self.hi_bound

    # -- updates ---------------------------------------------------------------
    def record(self, k: int, score: float, resource: int = 0) -> VisitRecord:
        """Append to ranks_seen and fold the score into the prune bounds."""
        rec = VisitRecord(k=k, score=score, resource=resource, wall_order=self._order_ctr)
        self._order_ctr += 1
        if self.space.selects(score):
            rec.pruned_lower = True
            if k > self.lo_bound:
                self.lo_bound = k
            if self.k_optimal is None or k > self.k_optimal:
                self.k_optimal = k
        if self.space.stops(score):
            rec.pruned_upper = True
            if k < self.hi_bound:
                self.hi_bound = k
        self.visits.append(rec)
        self._metrics.inc("ks_visited")
        self._tracer.event(
            "record", k=k, score=score, resource=resource,
            pruned_lower=rec.pruned_lower, pruned_upper=rec.pruned_upper,
        )
        if rec.pruned_lower or rec.pruned_upper:
            self._metrics.set_gauge("lo_bound", self.lo_bound)
            self._metrics.set_gauge("hi_bound", self.hi_bound)
        return rec

    def skip(self, k: int, reason: str = "pruned") -> None:
        """Account a k pruned before evaluation (the paper's cost saved)."""
        self._metrics.inc("ks_skipped")
        self._tracer.event("skip", k=k, reason=reason)

    def skip_interval(self, k_lo: int, k_hi: int, count: int) -> None:
        """Account a whole pruned subtree ([k_lo, k_hi], ``count`` ks) at once."""
        self._metrics.inc("ks_skipped", count)
        self._tracer.event("subtree_prune", k_lo=k_lo, k_hi=k_hi, count=count)

    def merge_bounds(self, lo_bound: float, hi_bound: float, k_optimal: int | None) -> None:
        """Fold prune bounds published by another resource (Alg 3/4 receive)."""
        lo = max(self.lo_bound, lo_bound)
        hi = min(self.hi_bound, hi_bound)
        if lo != self.lo_bound or hi != self.hi_bound:
            self._metrics.inc("bound_merges")
            self._tracer.event(
                "bound_merge", lo_before=self.lo_bound, hi_before=self.hi_bound,
                lo_after=lo, hi_after=hi,
            )
        self.lo_bound = lo
        self.hi_bound = hi
        if k_optimal is not None and (self.k_optimal is None or k_optimal > self.k_optimal):
            self.k_optimal = k_optimal

    def result(self) -> SearchResult:
        return SearchResult(
            k_optimal=self.k_optimal,
            visits=list(self.visits),
            n_candidates=len(self.space.ks),
        )


def binary_bleed_recursive(
    space: SearchSpace,
    evaluate: EvalFn,
    bleed_up_first: bool = True,
) -> SearchResult:
    """Paper Algorithm 1 — recursive Binary Bleed over ``space.ks``.

    ``bleed_up_first=True`` recurses into the upper half before the lower
    half (Alg 1 lines 16-19): for the max-k objective, finding a higher
    selecting k first prunes more of the lower half.
    """
    from .evalplane import as_eval_plane  # lazy: evalplane sits below bleed

    ks = space.ks
    state = BleedState(space)
    plane = as_eval_plane(evaluate)

    def search(lo: int, hi: int) -> None:  # [lo, hi) index interval
        if lo >= hi:
            return
        # subtree prune: whole k interval outside live bounds (Alg 1 l.16/18)
        if not state.interval_alive(ks[lo], ks[hi - 1]):
            state.skip_interval(ks[lo], ks[hi - 1], hi - lo)
            return
        mid = lo + (hi - lo) // 2
        k_mid = ks[mid]
        if state.should_visit(k_mid):  # Alg 1 line 7
            state.record(k_mid, plane.evaluate_one(k_mid))  # lines 8-15
        else:
            state.skip(k_mid)
        halves = ((mid + 1, hi), (lo, mid)) if bleed_up_first else ((lo, mid), (mid + 1, hi))
        for a, b in halves:  # lines 16-19: bleed into both directions
            search(a, b)

    # Python recursion depth is log2(|K|) — fine for any practical K, but we
    # guard absurd sizes by falling back to the worklist form.
    if len(ks) > 1 << 20:
        return binary_bleed_worklist(space, evaluate, order="pre")
    search(0, len(ks))
    return state.result()


def binary_bleed_worklist(
    space: SearchSpace,
    evaluate: EvalFn,
    order: Order = "pre",
    worklist: Sequence[int] | None = None,
    state: BleedState | None = None,
) -> SearchResult:
    """Iterative Binary Bleed: visit `worklist` (default: traversal-sorted
    ks), skipping pruned entries. With ``order="pre"`` this evaluates the
    same midpoints as the recursion; ``order="in"`` degrades to the naive
    linear grid search (the paper's Standard baseline).

    Passing an external ``state`` lets callers resume a checkpointed search
    or share bounds across resources (the scheduler does both).
    """
    from .evalplane import as_eval_plane  # lazy: evalplane sits below bleed

    if worklist is None:
        worklist = traversal_sort(sorted(space.ks), order)
    state = state if state is not None else BleedState(space)
    plane = as_eval_plane(evaluate)
    for k in worklist:
        if not state.should_visit(k):
            state.skip(k)
            continue
        state.record(k, plane.evaluate_one(k))
    return state.result()


def standard_search(space: SearchSpace, evaluate: EvalFn) -> SearchResult:
    """The paper's Standard baseline: exhaustive ascending grid search.

    Visits 100% of K and picks k_opt = max{k : S(f(k)) crosses T}.
    """
    from .evalplane import as_eval_plane  # lazy: evalplane sits below bleed

    state = BleedState(space)
    plane = as_eval_plane(evaluate)
    for k in space.ks:
        state.record(k, plane.evaluate_one(k))
        # Standard never prunes: reset bounds so every k is visited.
        state.lo_bound = -math.inf
        state.hi_bound = math.inf
    return state.result()
