"""Binary-tree traversal sorts of a k list (paper Fig. 1, Table II).

A sorted list of k values is viewed as the binary-search tree over index
intervals ``[lo, hi)`` (exclusive right) with root ``mid = lo + (hi-lo)//2``
and children ``[lo, mid)`` / ``[mid+1, hi)`` — exactly Algorithm 1's
midpoint convention, so traversal-sorted worklists visit the same nodes the
recursive algorithm would. This convention reproduces the paper's Table II
exactly: pre-order of [1..11] is ``6,3,2,1,5,4,9,8,7,11,10``.

  - pre-order : root, left, right — midpoints first; maximally informative
                early visits, the paper's best performer.
  - in-order  : left, root, right — recovers ascending order; equivalent to
                naive grid search (never prunes ahead).
  - post-order: left, right, root — children before parents.
"""
from __future__ import annotations

from typing import Iterator, Sequence

Order = str  # "pre" | "in" | "post"

_ORDERS = ("pre", "in", "post")


def _check_order(order: Order) -> None:
    if order not in _ORDERS:
        raise ValueError(f"order must be one of {_ORDERS}, got {order!r}")


def traversal_sort(ks: Sequence[int], order: Order = "pre") -> list[int]:
    """Reorder `ks` (assumed sorted ascending) by BST traversal.

    Iterative to avoid Python recursion limits on large K (distributed rank
    sweeps use |K| up to 1e5).
    """
    _check_order(order)
    ks = list(ks)
    n = len(ks)
    if n <= 1:
        return ks
    if order == "in":
        return ks

    out: list[int] = []
    if order == "pre":
        # root, left, right over [lo, hi) intervals
        stack: list[tuple[int, int]] = [(0, n)]
        while stack:
            lo, hi = stack.pop()
            if lo >= hi:
                continue
            mid = lo + (hi - lo) // 2
            out.append(ks[mid])
            stack.append((mid + 1, hi))  # right pushed first ...
            stack.append((lo, mid))  # ... so left pops first
        return out

    # post-order: left, right, root — two-phase stack
    stack2: list[tuple[int, int, bool]] = [(0, n, False)]
    while stack2:
        lo, hi, expanded = stack2.pop()
        if lo >= hi:
            continue
        mid = lo + (hi - lo) // 2
        if expanded:
            out.append(ks[mid])
        else:
            stack2.append((lo, hi, True))
            stack2.append((mid + 1, hi, False))
            stack2.append((lo, mid, False))
    return out


def traversal_iter(ks: Sequence[int], order: Order = "pre") -> Iterator[int]:
    yield from traversal_sort(ks, order)


def inverse_visit_rank(ks: Sequence[int], order: Order = "pre") -> dict[int, int]:
    """Map k -> position in the traversal order (0 = visited first)."""
    return {k: i for i, k in enumerate(traversal_sort(ks, order))}
