"""Shared prune-state coordination (paper Alg 3/4's Redis / MPI broadcast).

The paper shares ``k_min`` / ``k_max`` / ``k_optimal`` across threads via a
mutex and across MPI ranks via broadcast, suggesting "a distributed cache
such as reddis". On a TPU cluster we avoid an external service:

  * ``InProcessCoordinator`` — lock-protected state for threads in one
    process (Alg 4's mutex).
  * ``FileCoordinator`` — a tiny atomic-rename JSON KV on shared storage
    for multi-host searches (each pod slice is a host-level "rank"); also
    doubles as the fault-tolerance journal: every visit is appended to a
    log so a restarted search replays all pruning decisions (checkpoint/
    restart of the *search* itself, not just the model fits).

Both expose the same interface: ``publish(...)`` merges monotone bounds
(lo only rises, hi only falls, k_optimal only rises) and ``snapshot()``
returns the current global bounds. Monotonicity makes merges commutative —
stale publishes are harmless, which is what makes the distributed version
coordination-light (the paper's broadcast can arrive in any order).
"""
from __future__ import annotations

import json
import math
import os
import threading
import time
from typing import Iterable, NamedTuple

from repro.obs import get_metrics, get_tracer


class Bounds(NamedTuple):
    lo_bound: float  # ks <= lo_bound pruned (select crossings)
    hi_bound: float  # ks >= hi_bound pruned (stop crossings)
    k_optimal: int | None

    @staticmethod
    def empty() -> "Bounds":
        return Bounds(-math.inf, math.inf, None)

    def merge(self, other: "Bounds") -> "Bounds":
        k_opt = self.k_optimal
        if other.k_optimal is not None and (k_opt is None or other.k_optimal > k_opt):
            k_opt = other.k_optimal
        return Bounds(
            max(self.lo_bound, other.lo_bound),
            min(self.hi_bound, other.hi_bound),
            k_opt,
        )


class InProcessCoordinator:
    """Mutex-guarded shared bounds for thread resources (Alg 4)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._bounds = Bounds.empty()
        self._visits: list[tuple[int, float, int]] = []  # (k, score, resource)

    def publish(self, bounds: Bounds) -> Bounds:
        metrics = get_metrics()
        t0 = time.perf_counter()
        self._lock.acquire()
        t_locked = time.perf_counter()
        try:
            self._bounds = self._bounds.merge(bounds)
            merged = self._bounds
        finally:
            self._lock.release()
        metrics.observe("lock_wait_s", t_locked - t0)
        metrics.observe("publish_latency_s", time.perf_counter() - t0)
        metrics.inc("publish_count")
        return merged

    def record_visit(self, k: int, score: float, resource: int) -> None:
        with self._lock:
            self._visits.append((k, score, resource))

    def snapshot(self) -> Bounds:
        with self._lock:
            return self._bounds

    def visits(self) -> list[tuple[int, float, int]]:
        with self._lock:
            return list(self._visits)


class FileCoordinator:
    """Atomic-rename JSON KV + append-only journal on shared storage.

    Safe for concurrent writers on POSIX filesystems: state updates are
    read-merge-write with an exclusive lockfile; the journal is O_APPEND.
    This replaces the paper's Redis suggestion with zero extra services —
    on an HPC/TPU cluster the shared filesystem already exists.
    """

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._state_path = os.path.join(root, "bounds.json")
        self._journal_path = os.path.join(root, "journal.ndjson")
        self._lock_path = os.path.join(root, "bounds.lock")

    # -- tiny lockfile (NFS-safe enough: O_CREAT|O_EXCL with stale timeout) ----
    def _acquire(self, timeout: float = 10.0, stale: float = 30.0) -> None:
        deadline = time.time() + timeout
        t_wait0 = time.perf_counter()
        while True:
            try:
                fd = os.open(self._lock_path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                os.write(fd, str(os.getpid()).encode())
                os.close(fd)
                get_metrics().observe("lock_wait_s", time.perf_counter() - t_wait0)
                return
            except FileExistsError:
                try:
                    st = os.stat(self._lock_path)
                except FileNotFoundError:
                    continue
                age = time.time() - st.st_mtime
                if age > stale:
                    # Break the dead holder's lock — but only if it is still
                    # the SAME file we just stat'ed. Two waiters can both see
                    # a stale lock; the first unlinks it and wins the O_EXCL
                    # retry, and without this re-check the second would
                    # unlink the winner's FRESH lock and "acquire" too.
                    try:
                        st2 = os.stat(self._lock_path)
                        if (st2.st_ino, st2.st_mtime_ns) == (st.st_ino, st.st_mtime_ns):
                            os.unlink(self._lock_path)
                            get_metrics().inc("lock_broken")
                            get_tracer().event(
                                "lock_broken", path=self._lock_path, age_s=round(age, 3)
                            )
                    except FileNotFoundError:
                        pass  # another waiter broke it first
                    continue
                if time.time() > deadline:
                    raise TimeoutError(f"lock {self._lock_path} busy")
                time.sleep(0.005)

    def _release(self) -> None:
        try:
            os.unlink(self._lock_path)
        except FileNotFoundError:
            pass

    def _read_state(self) -> Bounds:
        try:
            with open(self._state_path) as f:
                d = json.load(f)
            return Bounds(d["lo"], d["hi"], d["k_optimal"])
        except (FileNotFoundError, json.JSONDecodeError, KeyError):
            return Bounds.empty()

    def _write_state(self, b: Bounds) -> None:
        tmp = self._state_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"lo": b.lo_bound, "hi": b.hi_bound, "k_optimal": b.k_optimal}, f)
        os.replace(tmp, self._state_path)  # atomic on POSIX

    # -- public API -------------------------------------------------------------
    def publish(self, bounds: Bounds) -> Bounds:
        metrics = get_metrics()
        t0 = time.perf_counter()
        self._acquire()
        try:
            merged = self._read_state().merge(bounds)
            self._write_state(merged)
        finally:
            self._release()
        metrics.observe("publish_latency_s", time.perf_counter() - t0)
        metrics.inc("publish_count")
        return merged

    def snapshot(self) -> Bounds:
        return self._read_state()

    def record_visit(self, k: int, score: float, resource: int) -> None:
        line = json.dumps({"k": k, "score": score, "resource": resource, "t": time.time()})
        with open(self._journal_path, "a") as f:
            f.write(line + "\n")

    def visits(self) -> list[tuple[int, float, int]]:
        out = []
        try:
            with open(self._journal_path) as f:
                for line in f:
                    if not line.strip():
                        continue
                    d = json.loads(line)
                    out.append((d["k"], d["score"], d["resource"]))
        except FileNotFoundError:
            pass
        return out

    # -- restart ------------------------------------------------------------------
    def replay(self, selects, stops) -> tuple[Bounds, set[int]]:
        """Rebuild bounds + visited set from the journal (search restart).

        ``selects`` / ``stops`` are the SearchSpace threshold predicates; we
        re-apply them so a restart with *tightened* thresholds re-prunes
        correctly rather than trusting stale bounds.
        """
        b = Bounds.empty()
        visited: set[int] = set()
        for k, score, _ in self.visits():
            visited.add(k)
            lo = k if selects(score) else -math.inf
            hi = k if stops(score) else math.inf
            k_opt = k if selects(score) else None
            b = b.merge(Bounds(lo, hi, k_opt))
        self.publish(b)
        return b, visited


def merge_all(bounds: Iterable[Bounds]) -> Bounds:
    out = Bounds.empty()
    for b in bounds:
        out = out.merge(b)
    return out
