"""Public Binary Bleed API.

    from repro.core import binary_bleed_search, SearchSpace, Mode

    result = binary_bleed_search(
        evaluate=lambda k: my_model_score(k),
        k_range=(2, 30),
        select_threshold=0.7,
        stop_threshold=0.2,          # optional Early Stop (§III-C)
        mode="maximize",
        num_resources=4,             # 1 = serial Algorithm 1
        order="pre",
    )
    result.k_optimal, result.visit_fraction

Executors: serial worklist (num_resources=1), "threads" (one fit per k per
worker thread), "simulate" (deterministic discrete-event), and "batched" —
the wavefront executor, which dispatches each frontier of live midpoints as
one ``evaluate_batch`` call against an ``EvalPlane`` (e.g. the mask-padded
vmapped fits in ``repro.factorization.planes``), amortizing trace/JIT/
dispatch across every k in the wave.
"""
from __future__ import annotations

from typing import Callable, Sequence

from .bleed import binary_bleed_recursive, binary_bleed_worklist, standard_search
from .evalplane import (
    ElasticWavefrontScheduler,
    EvalPlane,
    ScalarEvalPlane,
    WavefrontScheduler,
    as_eval_plane,
)
from .scheduler import (
    LaneRefillPolicy,
    ScheduleTrace,
    SimulatedScheduler,
    ThreadPoolScheduler,
)
from .search_space import Mode, SearchResult, SearchSpace
from .traversal import Order


def make_space(
    k_range: tuple[int, int] | Sequence[int],
    select_threshold: float,
    stop_threshold: float | None = None,
    mode: str | Mode = Mode.MAXIMIZE,
) -> SearchSpace:
    mode = Mode(mode)
    if isinstance(k_range, tuple) and len(k_range) == 2 and isinstance(k_range[0], int):
        ks = tuple(range(k_range[0], k_range[1] + 1))
    else:
        ks = tuple(sorted(set(int(k) for k in k_range)))
    return SearchSpace(ks, select_threshold, stop_threshold, mode)


def binary_bleed_search(
    evaluate: Callable[..., float],
    k_range: tuple[int, int] | Sequence[int],
    select_threshold: float,
    stop_threshold: float | None = None,
    mode: str | Mode = Mode.MAXIMIZE,
    num_resources: int = 1,
    order: Order = "pre",
    strategy: str = "T4",
    executor: str = "threads",
    max_wave: int | None = None,
) -> SearchResult:
    """Run Binary Bleed over k_range; returns SearchResult.

    Executors:

    * ``"threads"`` (default) — ``num_resources`` worker threads, each
      walking a T4 worklist and fitting one k at a time; prune bounds are
      shared through a coordinator. ``num_resources == 1`` runs the serial
      Algorithm 1 (worklist form) instead.
    * ``"simulate"`` — deterministic discrete-event simulation of the same
      plan (used by benchmarks; evaluation still happens exactly once per
      visited k).
    * ``"batched"`` — the wavefront executor: the frontier of live subtree
      midpoints is dispatched as ONE ``evaluate_batch`` call per wave, so a
      single padded/vmapped fit (e.g. ``repro.factorization.planes``)
      serves every k in the wave with one jit compilation. ``evaluate``
      may be a scalar callable (batched trivially) or any ``EvalPlane``;
      ``max_wave`` caps the ks per dispatch. ``num_resources`` is ignored —
      parallelism comes from the batch axis, not threads.
    * ``"elastic"`` — continuous batching over fit-chunks: ``evaluate``
      must be an elastic plane (``submit``/``cancel``/``tick`` — e.g.
      ``repro.factorization.planes.NMFkElasticPlane``). Lanes retire on
      per-fit convergence, freed slots refill from the pre-order worklist
      (``order`` is taken from the plane-side ``LaneRefillPolicy``), and
      prunes evict in-flight ks mid-fit.
    """
    space = make_space(k_range, select_threshold, stop_threshold, mode)
    if executor == "batched":
        return WavefrontScheduler(space, max_wave=max_wave).run(evaluate)
    if executor == "elastic":
        return ElasticWavefrontScheduler(space, refill=LaneRefillPolicy(order=order)).run(evaluate)
    if num_resources <= 1:
        return binary_bleed_worklist(space, evaluate, order=order)
    if executor == "threads":
        return ThreadPoolScheduler(space, num_resources, order, strategy).run(evaluate)
    if executor == "simulate":
        trace = SimulatedScheduler(space, num_resources, order, strategy).run(evaluate)
        return trace.to_result()
    raise ValueError(f"unknown executor {executor!r}")


def grid_search(
    evaluate: Callable[[int], float],
    k_range: tuple[int, int] | Sequence[int],
    select_threshold: float,
    mode: str | Mode = Mode.MAXIMIZE,
) -> SearchResult:
    """The paper's Standard baseline (visits 100% of K)."""
    return standard_search(make_space(k_range, select_threshold, None, mode), evaluate)


__all__ = [
    "binary_bleed_search",
    "grid_search",
    "make_space",
    "binary_bleed_recursive",
    "binary_bleed_worklist",
    "standard_search",
    "EvalPlane",
    "ScalarEvalPlane",
    "WavefrontScheduler",
    "ElasticWavefrontScheduler",
    "LaneRefillPolicy",
    "as_eval_plane",
    "SimulatedScheduler",
    "ThreadPoolScheduler",
    "ScheduleTrace",
    "SearchSpace",
    "SearchResult",
    "Mode",
]
