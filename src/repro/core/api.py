"""Public Binary Bleed API.

    from repro.core import binary_bleed_search, SearchSpace, Mode

    result = binary_bleed_search(
        evaluate=lambda k: my_model_score(k),
        k_range=(2, 30),
        select_threshold=0.7,
        stop_threshold=0.2,          # optional Early Stop (§III-C)
        mode="maximize",
        num_resources=4,             # 1 = serial Algorithm 1
        order="pre",
    )
    result.k_optimal, result.visit_fraction
"""
from __future__ import annotations

from typing import Callable, Sequence

from .bleed import binary_bleed_recursive, binary_bleed_worklist, standard_search
from .scheduler import ScheduleTrace, SimulatedScheduler, ThreadPoolScheduler
from .search_space import Mode, SearchResult, SearchSpace
from .traversal import Order


def make_space(
    k_range: tuple[int, int] | Sequence[int],
    select_threshold: float,
    stop_threshold: float | None = None,
    mode: str | Mode = Mode.MAXIMIZE,
) -> SearchSpace:
    mode = Mode(mode)
    if isinstance(k_range, tuple) and len(k_range) == 2 and isinstance(k_range[0], int):
        ks = tuple(range(k_range[0], k_range[1] + 1))
    else:
        ks = tuple(sorted(set(int(k) for k in k_range)))
    return SearchSpace(ks, select_threshold, stop_threshold, mode)


def binary_bleed_search(
    evaluate: Callable[..., float],
    k_range: tuple[int, int] | Sequence[int],
    select_threshold: float,
    stop_threshold: float | None = None,
    mode: str | Mode = Mode.MAXIMIZE,
    num_resources: int = 1,
    order: Order = "pre",
    strategy: str = "T4",
    executor: str = "threads",
) -> SearchResult:
    """Run Binary Bleed over k_range; returns SearchResult.

    ``num_resources == 1`` runs the serial Algorithm 1 (worklist form).
    Otherwise resources execute concurrently (``executor="threads"``) or
    deterministically in simulation (``executor="simulate"`` — used by
    benchmarks; evaluation still happens exactly once per visited k).
    """
    space = make_space(k_range, select_threshold, stop_threshold, mode)
    if num_resources <= 1:
        return binary_bleed_worklist(space, evaluate, order=order)
    if executor == "threads":
        return ThreadPoolScheduler(space, num_resources, order, strategy).run(evaluate)
    if executor == "simulate":
        trace = SimulatedScheduler(space, num_resources, order, strategy).run(evaluate)
        return trace.to_result()
    raise ValueError(f"unknown executor {executor!r}")


def grid_search(
    evaluate: Callable[[int], float],
    k_range: tuple[int, int] | Sequence[int],
    select_threshold: float,
    mode: str | Mode = Mode.MAXIMIZE,
) -> SearchResult:
    """The paper's Standard baseline (visits 100% of K)."""
    return standard_search(make_space(k_range, select_threshold, None, mode), evaluate)


__all__ = [
    "binary_bleed_search",
    "grid_search",
    "make_space",
    "binary_bleed_recursive",
    "binary_bleed_worklist",
    "standard_search",
    "SimulatedScheduler",
    "ThreadPoolScheduler",
    "ScheduleTrace",
    "SearchSpace",
    "SearchResult",
    "Mode",
]
