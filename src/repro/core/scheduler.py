"""Multi-resource Binary Bleed scheduler (paper Algorithms 3 & 4).

Two executors over the same plan (Alg 2 chunking + traversal sort, T4):

  * ``SimulatedScheduler`` — a deterministic discrete-event simulator used
    by the reproduction benchmarks (Figs 2-6 operation dynamics, Fig 7/8
    visit percentages, Fig 9 distributed runtimes). Each "resource" is a
    mesh slice / MPI rank / thread; fit durations come from a user model
    (e.g. measured per-k NMF times). Broadcast of prune bounds is
    instantaneous on completion, matching the paper's implementation where
    in-flight fits are NOT aborted by default ("the implementation shown
    does not prune k values after the model begins execution", Fig 4) —
    optional ``abort_in_flight`` enables §III-D early termination.

  * ``ThreadPoolScheduler`` — real concurrency: one worker per resource
    walking its worklist, sharing bounds through a Coordinator
    (InProcess for threads, File for multi-host). Supports straggler
    speculation and elastic re-chunking on resource failure.

Fault-tolerance model: k evaluations are pure/idempotent (a model fit at a
given k with fixed seed), so (a) duplicated work is safe — first finisher
wins; (b) a dead resource's unvisited chunk can be re-dealt (Alg 2) over
the survivors; (c) the journal makes restarts exact.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable, Sequence

from repro.obs import get_metrics, get_tracer
from repro.obs.trace import Tracer

from .bleed import BleedState
from .chunking import plan_worklists, rebalance
from .coordinator import Bounds, InProcessCoordinator
from .evalplane import as_eval_plane
from .search_space import SearchResult, SearchSpace, VisitRecord
from .traversal import Order

EvalFn = Callable[[int], float]
DurationFn = Callable[[int], float]


@dataclasses.dataclass
class SimVisit:
    k: int
    score: float
    resource: int
    t_start: float
    t_end: float
    aborted: bool = False  # started, then pruned mid-flight (§III-D)


@dataclasses.dataclass
class ScheduleTrace:
    """Full account of a simulated run — the benchmark's ground truth."""

    k_optimal: int | None
    visits: list[SimVisit]  # completed evaluations (cost incurred)
    aborted: list[SimVisit]  # partial evaluations (cost partially incurred)
    skipped: list[int]  # pruned before starting (cost saved)
    makespan: float
    n_candidates: int
    busy_time: float  # sum of evaluation time across resources
    num_resources: int

    @property
    def n_visited(self) -> int:
        return len(self.visits) + len(self.aborted)

    @property
    def visit_fraction(self) -> float:
        return self.n_visited / max(1, self.n_candidates)

    def to_result(self) -> SearchResult:
        recs = [
            VisitRecord(k=v.k, score=v.score, resource=v.resource, wall_order=i)
            for i, v in enumerate(sorted(self.visits, key=lambda v: v.t_end))
        ]
        return SearchResult(self.k_optimal, recs, self.n_candidates)

    def to_tracer(self) -> Tracer:
        """Replay the simulated schedule into the live trace format.

        Logical sim seconds map to trace microseconds (1 s -> 1e6 us), one
        track per resource — the same shape a live ``ThreadPoolScheduler``
        run produces, so simulated and real schedules open side by side in
        Perfetto / ``chrome://tracing``.
        """
        tracer = Tracer()
        for v in sorted(self.visits + self.aborted, key=lambda v: (v.t_start, v.k)):
            tracer.add_span(
                "fit", v.t_start * 1e6, (v.t_end - v.t_start) * 1e6,
                track=f"resource-{v.resource}", k=v.k, score=v.score, aborted=v.aborted,
            )
            if v.aborted:
                tracer.add_event("abort", v.t_end * 1e6, track=f"resource-{v.resource}", k=v.k)
        if self.skipped:
            tracer.add_event(
                "skipped", self.makespan * 1e6, track="scheduler",
                count=len(self.skipped), ks=list(self.skipped),
            )
        return tracer

    def export_perfetto(self, path: str) -> int:
        """Write the schedule as Chrome-trace JSON; returns #events."""
        return self.to_tracer().export_perfetto(path)


@dataclasses.dataclass
class ResourceEvent:
    """Elasticity event: at time t, resource `rid` fails or a new one joins."""

    t: float
    kind: str  # "fail" | "join"
    rid: int


class SimulatedScheduler:
    """Deterministic discrete-event execution of multi-resource Binary Bleed."""

    def __init__(
        self,
        space: SearchSpace,
        num_resources: int,
        order: Order = "pre",
        strategy: str = "T4",
        duration_fn: DurationFn | None = None,
        abort_in_flight: bool = False,
        speculate_stragglers: bool = False,
        events: Sequence[ResourceEvent] = (),
    ):
        self.space = space
        self.num_resources = num_resources
        self.order = order
        self.strategy = strategy
        self.duration_fn = duration_fn or (lambda k: 1.0)
        self.abort_in_flight = abort_in_flight
        self.speculate = speculate_stragglers
        self.events = sorted(events, key=lambda e: e.t)

    def run(self, evaluate: EvalFn) -> ScheduleTrace:
        plane = as_eval_plane(evaluate)
        state = BleedState(self.space)
        worklists = plan_worklists(self.space.ks, self.num_resources, self.order, self.strategy)
        queues: dict[int, list[int]] = {r: list(w) for r, w in enumerate(worklists)}
        alive: set[int] = set(queues)
        running: dict[int, tuple[int, float, float]] = {}  # rid -> (k, t_start, t_end)
        in_flight_ks: dict[int, list[int]] = {}  # k -> [rids] (speculation dups)
        visits: list[SimVisit] = []
        aborted: list[SimVisit] = []
        skipped: list[int] = []
        busy = 0.0
        now = 0.0
        next_rid = self.num_resources
        ev_i = 0
        started: set[int] = set()  # ks whose evaluation ever started
        scores: dict[int, float] = {}

        def pop_next(rid: int) -> int | None:
            q = queues.get(rid, [])
            while q:
                k = q.pop(0)
                if k in started:
                    continue
                if state.should_visit(k):
                    return k
                skipped.append(k)
            return None

        def dispatch(rid: int) -> None:
            if rid in running or rid not in alive:
                return
            k = pop_next(rid)
            if k is None and self.speculate:
                # straggler speculation: duplicate the in-flight k that will
                # finish last (idempotent fits; first finisher wins).
                cands = [
                    (t_end, kk)
                    for r2, (kk, _, t_end) in running.items()
                    if r2 != rid and state.should_visit(kk)
                ]
                if cands:
                    _, kk = max(cands)
                    dur = self.duration_fn(kk)
                    running[rid] = (kk, now, now + dur)
                    in_flight_ks.setdefault(kk, []).append(rid)
                    return
            if k is not None:
                dur = self.duration_fn(k)
                started.add(k)
                running[rid] = (k, now, now + dur)
                in_flight_ks.setdefault(k, []).append(rid)

        def handle_events_until(t: float) -> None:
            nonlocal ev_i, next_rid
            while ev_i < len(self.events) and self.events[ev_i].t <= t:
                ev = self.events[ev_i]
                ev_i += 1
                if ev.kind == "fail" and ev.rid in alive:
                    alive.discard(ev.rid)
                    # in-flight work lost: the k never completed, re-queue it
                    if ev.rid in running:
                        k, t_s, _ = running.pop(ev.rid)
                        dup_list = in_flight_ks.get(k, [])
                        if ev.rid in dup_list:
                            dup_list.remove(ev.rid)
                        if not dup_list:
                            started.discard(k)  # nobody else running it -> redo
                    # elastic re-chunk: pool unvisited ks over survivors (Alg 2)
                    pool = sorted(
                        {k for q in queues.values() for k in q if k not in started}
                    )
                    survivors = sorted(alive)
                    if survivors and pool:
                        new_lists = rebalance(pool, len(survivors), self.order)
                        for q in queues.values():
                            q.clear()
                        for r2, wl in zip(survivors, new_lists):
                            queues[r2] = list(wl)
                elif ev.kind == "join":
                    rid = next_rid
                    next_rid += 1
                    alive.add(rid)
                    queues[rid] = []
                    pool = sorted(
                        {k for q in queues.values() for k in q if k not in started}
                    )
                    survivors = sorted(alive)
                    if pool:
                        new_lists = rebalance(pool, len(survivors), self.order)
                        for q in queues.values():
                            q.clear()
                        for r2, wl in zip(survivors, new_lists):
                            queues[r2] = list(wl)

        handle_events_until(0.0)
        for rid in sorted(alive):
            dispatch(rid)

        while running:
            # advance to the earliest completion (or event)
            t_next = min(t_end for (_, _, t_end) in running.values())
            if ev_i < len(self.events) and self.events[ev_i].t < t_next:
                now = self.events[ev_i].t
                handle_events_until(now)
                for rid in sorted(alive):
                    dispatch(rid)
                continue
            now = t_next
            done = sorted(rid for rid, (_, _, te) in running.items() if te <= now)
            for rid in done:
                k, t_s, t_e = running.pop(rid)
                dup_list = in_flight_ks.get(k, [])
                if rid in dup_list:
                    dup_list.remove(rid)
                busy += t_e - t_s
                if k in scores:  # speculation duplicate finished second
                    continue
                score = plane.evaluate_one(k)
                scores[k] = score
                state.record(k, score, resource=rid)
                visits.append(SimVisit(k, score, rid, t_s, t_e))
                # duplicate runs of k elsewhere are now pointless — cancel
                for r2 in list(dup_list):
                    kk, ts2, _ = running.pop(r2)
                    busy += now - ts2
                    dup_list.remove(r2)
            if self.abort_in_flight:
                # §III-D: long fits poll prune state between chunks and exit
                for rid, (k, t_s, t_e) in list(running.items()):
                    if not state.should_visit(k):
                        running.pop(rid)
                        dup_list = in_flight_ks.get(k, [])
                        if rid in dup_list:
                            dup_list.remove(rid)
                        busy += now - t_s
                        aborted.append(SimVisit(k, float("nan"), rid, t_s, now, aborted=True))
            for rid in sorted(alive):
                dispatch(rid)

        # drain queues of never-started ks into skipped
        for q in queues.values():
            for k in q:
                if k not in started:
                    skipped.append(k)

        return ScheduleTrace(
            k_optimal=state.k_optimal,
            visits=visits,
            aborted=aborted,
            skipped=sorted(set(skipped)),
            makespan=now,
            n_candidates=len(self.space.ks),
            busy_time=busy,
            num_resources=self.num_resources,
        )


@dataclasses.dataclass
class LaneRefillPolicy:
    """When and what the elastic executor drains into freed lanes.

    The candidate stream is the Binary Bleed traversal worklist (pre-order
    by default — the order whose prefixes the serial and threaded drivers
    walk, so elastic refill preserves their visit semantics: admission only
    ever *filters* that stream against the live prune bounds, never
    reorders it). ``max_backlog`` bounds how many (k, perturbation) lanes
    may sit queued in the plane beyond its occupied slots — a small backlog
    keeps freed lanes refilling without host round-trips, while a large one
    admits ks so early that later prunes must evict them; ``None`` uses one
    slot-pool's worth (the plane's ``slots``).
    """

    order: Order = "pre"
    max_backlog: int | None = None

    def worklist(self, ks: Sequence[int]) -> list[int]:
        from .traversal import traversal_sort

        return traversal_sort(list(ks), self.order)

    def admit(self, plane) -> bool:
        cap = self.max_backlog if self.max_backlog is not None else getattr(plane, "slots", 1)
        return plane.backlog < cap


class ThreadPoolScheduler:
    """Real-concurrency Binary Bleed across thread resources (Alg 3/4).

    Each worker owns a T4 worklist; shared bounds live in a Coordinator.
    ``evaluate`` may accept a ``should_abort`` kwarg — a zero-arg callable
    it can poll between fit chunks (§III-D) to stop early when its k has
    been pruned by another resource.
    """

    def __init__(
        self,
        space: SearchSpace,
        num_resources: int,
        order: Order = "pre",
        strategy: str = "T4",
        coordinator=None,  # InProcessCoordinator | FileCoordinator (duck-typed)
    ):
        self.space = space
        self.num_resources = num_resources
        self.order = order
        self.strategy = strategy
        self.coordinator = coordinator if coordinator is not None else InProcessCoordinator()

    def run(self, evaluate: Callable[..., float], skip: set[int] | None = None) -> SearchResult:
        plane = as_eval_plane(evaluate)
        space = self.space
        coord = self.coordinator
        tracer = get_tracer()
        metrics = get_metrics()
        metrics.set_gauge("ks_candidates", len(space.ks))
        worklists = plan_worklists(space.ks, self.num_resources, self.order, self.strategy)
        errors: list[BaseException] = []

        def make_should_visit():
            def should_visit(k: int) -> bool:
                b = coord.snapshot()
                return b.lo_bound < k < b.hi_bound

            return should_visit

        def worker(rid: int, worklist: list[int]) -> None:
            track = f"resource-{rid}"
            should_visit = make_should_visit()

            def make_should_abort(k: int):
                # §III-D poll, instrumented: the first True is the abort
                # signal actually delivered to an in-flight fit — count it.
                fired = []

                def should_abort() -> bool:
                    pruned = not should_visit(k)
                    if pruned and not fired:
                        fired.append(True)
                        metrics.inc("ks_aborted")
                        tracer.event("abort", track=track, k=k)
                    return pruned

                return should_abort

            try:
                with tracer.span("worker", track=track, rid=rid, worklist_len=len(worklist)):
                    for k in worklist:
                        if skip and k in skip:  # journaled on a previous run
                            metrics.inc("ks_journaled")
                            continue
                        if not should_visit(k):
                            metrics.inc("ks_skipped")
                            tracer.event("skip", track=track, k=k, reason="pruned")
                            continue
                        t_fit = time.perf_counter()
                        with tracer.span("fit", track=track, k=k) as sp:
                            score = plane.evaluate_one(k, should_abort=make_should_abort(k))
                            sp.set(score=float(score))
                        metrics.observe("fit_seconds", time.perf_counter() - t_fit)
                        metrics.inc("ks_visited")
                        lo = k if space.selects(score) else -float("inf")
                        hi = k if space.stops(score) else float("inf")
                        k_opt = k if space.selects(score) else None
                        with tracer.span("publish", track=track, k=k):
                            coord.record_visit(k, float(score), rid)
                            coord.publish(Bounds(lo, hi, k_opt))
            except BaseException as e:  # surface worker crashes to the driver
                errors.append(e)

        threads = [
            threading.Thread(target=worker, args=(rid, wl), daemon=True)
            for rid, wl in enumerate(worklists)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]

        b = coord.snapshot()
        visits = [
            VisitRecord(k=k, score=s, resource=r, wall_order=i)
            for i, (k, s, r) in enumerate(coord.visits())
        ]
        return SearchResult(b.k_optimal, visits, len(space.ks))
