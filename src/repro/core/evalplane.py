"""Evaluation plane: the batched dispatch surface under every Bleed driver.

The paper treats "resources" as threads/ranks that each fit one k at a
time, so every distinct k pays its own trace/JIT/dispatch cost. On a
single accelerator the hardware-shaped alternative is to dispatch a whole
*frontier* of independent k values as one padded, vmapped fit. This module
defines the seam between the two worlds:

  * ``EvalPlane`` — protocol: ``evaluate_batch(ks) -> scores`` (plus a
    scalar ``evaluate_one`` used by the per-k drivers). Anything with an
    ``evaluate_batch`` method qualifies; the batched factorization planes
    (``repro.factorization.planes``) implement it with mask-padded vmapped
    fits, one jit compilation per padded shape.
  * ``ScalarEvalPlane`` — adapter wrapping today's scalar ``evaluate(k)``
    callables (optionally accepting ``should_abort``, §III-D) so the
    serial worklist, thread scheduler, and simulator all route through the
    same interface unchanged.
  * ``WavefrontScheduler`` — the batched executor: repeatedly collect the
    frontier of live subtree midpoints (independent under Alg 3/4
    semantics — no midpoint in a wave can prune another before scores
    land), dispatch them as one batch, fold every score into
    ``BleedState``, re-prune, and descend into the surviving subtrees.

Layering note: this module sits *below* ``bleed.py`` (which lazily imports
``as_eval_plane``), so it must not import ``bleed`` at module scope.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Protocol, Sequence, runtime_checkable

from repro.obs import get_metrics, get_tracer

from .search_space import SearchResult, SearchSpace

AbortFn = Callable[[], bool]


@runtime_checkable
class EvalPlane(Protocol):
    """A surface that scores candidate k values, possibly many at once."""

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        """Score each k in ``ks``; returns scores aligned with the input."""
        ...

    def evaluate_one(self, k: int, should_abort: AbortFn | None = None) -> float:
        """Score a single k (scalar drivers; ``should_abort`` per §III-D)."""
        ...


class ScalarEvalPlane:
    """Adapter: a scalar ``evaluate(k)`` callable as an ``EvalPlane``.

    Detects once whether the callable accepts the §III-D ``should_abort``
    kwarg and forwards it only then, preserving the historical contract of
    ``ThreadPoolScheduler.run``.
    """

    def __init__(self, fn: Callable[..., float]):
        self.fn = fn
        self.accepts_abort = False
        try:
            self.accepts_abort = "should_abort" in inspect.signature(fn).parameters
        except (TypeError, ValueError):
            pass

    def evaluate_one(self, k: int, should_abort: AbortFn | None = None) -> float:
        # forward only a real callback: passing should_abort=None would
        # override a callable default the evaluator polls unconditionally
        if should_abort is not None and self.accepts_abort:
            return float(self.fn(k, should_abort=should_abort))
        return float(self.fn(k))

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        return [self.evaluate_one(k) for k in ks]


class _BatchOnlyAdapter:
    """Gives batch-only planes the scalar entry point the drivers expect."""

    def __init__(self, plane):
        self.plane = plane

    def evaluate_one(self, k: int, should_abort: AbortFn | None = None) -> float:
        del should_abort  # batched fits have no chunk boundary to poll
        return float(self.plane.evaluate_batch([k])[0])

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        return self.plane.evaluate_batch(ks)

    @property
    def last_lane_utilization(self):
        return getattr(self.plane, "last_lane_utilization", None)


def as_eval_plane(evaluate) -> EvalPlane:
    """Coerce a scalar callable or an EvalPlane-shaped object to EvalPlane."""
    if hasattr(evaluate, "evaluate_batch"):
        if hasattr(evaluate, "evaluate_one"):
            return evaluate
        return _BatchOnlyAdapter(evaluate)
    if callable(evaluate):
        return ScalarEvalPlane(evaluate)
    raise TypeError(f"cannot use {type(evaluate).__name__} as an evaluation plane")


@dataclasses.dataclass
class Wave:
    """One dispatched frontier: the ks sent together and their scores."""

    index: int
    ks: list[int]
    scores: list[float]
    lo_bound: float  # prune bounds after folding this wave's scores
    hi_bound: float


class WavefrontScheduler:
    """Batched Binary Bleed: evaluate frontiers of live midpoints as waves.

    Walks the same binary tree over ``space.ks`` as Algorithm 1, but
    breadth-first: the midpoints of all currently-live index intervals are
    independent (none is an ancestor of another), so they are dispatched to
    the plane as one ``evaluate_batch`` call. All returned scores are folded
    into the shared ``BleedState``, subtrees falling outside the updated
    bounds are dropped, and the next wave is the midpoints of the surviving
    children. Wave w holds at most 2^w entries, so a full run issues at most
    ceil(log2(|K|))+1 batch dispatches instead of one per visited k.

    Compared to the serial driver this may evaluate ks a just-landed wave
    would have pruned (same trade as the paper's multi-resource runs — a
    wave is "resources" executing concurrently), so visits form a superset
    of the serial schedule's but remain a subset of the pre-order worklist,
    and pruning soundness (pruned ks cannot be optimal) keeps ``k_optimal``
    identical for threshold-separable score shapes.

    ``max_wave`` caps the number of ks per dispatch (e.g. device memory);
    chunks of one wave re-check the prune state between dispatches, highest
    k first (``bleed_up_first``) since for the max-k objective high
    selecting ks prune the most.
    """

    def __init__(
        self,
        space: SearchSpace,
        max_wave: int | None = None,
        bleed_up_first: bool = True,
        tracer=None,
        metrics=None,
    ):
        if max_wave is not None and max_wave < 1:
            raise ValueError("max_wave must be >= 1")
        self.space = space
        self.max_wave = max_wave
        self.bleed_up_first = bleed_up_first
        self.waves: list[Wave] = []
        self._tracer = tracer
        self._metrics = metrics

    def run(self, evaluate, state=None) -> SearchResult:
        from .bleed import BleedState  # lazy: bleed sits above this module

        tracer = self._tracer if self._tracer is not None else get_tracer()
        metrics = self._metrics if self._metrics is not None else get_metrics()
        plane = as_eval_plane(evaluate)
        # tell capacity-aware planes the dispatch bound so their batch
        # padding (a compile-reuse optimization) never exceeds it; assign
        # unconditionally so a reused plane doesn't keep a stale cap
        if hasattr(plane, "dispatch_cap"):
            plane.dispatch_cap = self.max_wave
        space = self.space
        ks = space.ks
        state = state if state is not None else BleedState(space, tracer=tracer, metrics=metrics)
        self.waves = []
        wave_idx = 0
        intervals: list[tuple[int, int]] = [(0, len(ks))]  # [lo, hi) index spans

        while intervals:
            live = []
            for lo, hi in intervals:
                if lo >= hi:
                    continue
                if state.interval_alive(ks[lo], ks[hi - 1]):
                    live.append((lo, hi))
                else:
                    state.skip_interval(ks[lo], ks[hi - 1], hi - lo)
            mids = [lo + (hi - lo) // 2 for lo, hi in live]
            pending = []
            for m in mids:
                if state.should_visit(ks[m]):
                    pending.append(ks[m])
                else:
                    state.skip(ks[m])
            pending.sort(reverse=self.bleed_up_first)
            step = self.max_wave if self.max_wave is not None else max(len(pending), 1)
            for start in range(0, len(pending), step):
                # re-filter: earlier chunks of this wave may have pruned these
                chunk = []
                for k in pending[start : start + step]:
                    if state.should_visit(k):
                        chunk.append(k)
                    else:
                        state.skip(k, reason="pruned_by_chunk")
                if not chunk:
                    continue
                with tracer.span("wave", track="wavefront", wave=wave_idx, size=len(chunk),
                                 k_lo=min(chunk), k_hi=max(chunk)):
                    scores = plane.evaluate_batch(chunk)
                if len(scores) != len(chunk):
                    raise ValueError(
                        f"evaluate_batch returned {len(scores)} scores for {len(chunk)} ks"
                    )
                metrics.observe("wave_size", len(chunk))
                # mesh-sharded planes report real/dispatched lanes of the
                # dispatch they just ran; surface it as a live gauge next to
                # the wave_size histogram
                util = getattr(plane, "last_lane_utilization", None)
                if util is not None:
                    metrics.set_gauge("lane_utilization", float(util))
                with tracer.span("publish", track="wavefront", wave=wave_idx):
                    for k, score in zip(chunk, scores):
                        state.record(k, float(score), resource=wave_idx)
                self.waves.append(
                    Wave(wave_idx, list(chunk), [float(s) for s in scores],
                         state.lo_bound, state.hi_bound)
                )
                wave_idx += 1
            # descend: children of every live interval (midpoint evaluated or
            # not — Alg 1 recurses regardless); dead ones are filtered above.
            nxt: list[tuple[int, int]] = []
            for (lo, hi), mid in zip(live, mids):
                halves = ((mid + 1, hi), (lo, mid)) if self.bleed_up_first else ((lo, mid), (mid + 1, hi))
                nxt.extend(h for h in halves if h[0] < h[1])
            intervals = nxt

        return state.result()

    @property
    def n_dispatches(self) -> int:
        """Number of batch dispatches issued by the last ``run``."""
        return len(self.waves)


__all__ = [
    "EvalPlane",
    "ScalarEvalPlane",
    "WavefrontScheduler",
    "Wave",
    "as_eval_plane",
]
