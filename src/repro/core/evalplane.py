"""Evaluation plane: the batched dispatch surface under every Bleed driver.

The paper treats "resources" as threads/ranks that each fit one k at a
time, so every distinct k pays its own trace/JIT/dispatch cost. On a
single accelerator the hardware-shaped alternative is to dispatch a whole
*frontier* of independent k values as one padded, vmapped fit. This module
defines the seam between the two worlds:

  * ``EvalPlane`` — protocol: ``evaluate_batch(ks) -> scores`` (plus a
    scalar ``evaluate_one`` used by the per-k drivers). Anything with an
    ``evaluate_batch`` method qualifies; the batched factorization planes
    (``repro.factorization.planes``) implement it with mask-padded vmapped
    fits, one jit compilation per padded shape.
  * ``ScalarEvalPlane`` — adapter wrapping today's scalar ``evaluate(k)``
    callables (optionally accepting ``should_abort``, §III-D) so the
    serial worklist, thread scheduler, and simulator all route through the
    same interface unchanged.
  * ``WavefrontScheduler`` — the batched executor: repeatedly collect the
    frontier of live subtree midpoints (independent under Alg 3/4
    semantics — no midpoint in a wave can prune another before scores
    land), dispatch them as one batch, fold every score into
    ``BleedState``, re-prune, and descend into the surviving subtrees.

Layering note: this module sits *below* ``bleed.py`` (which lazily imports
``as_eval_plane``), so it must not import ``bleed`` at module scope.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Protocol, Sequence, runtime_checkable

from repro.obs import get_metrics, get_tracer

from .search_space import SearchResult, SearchSpace

AbortFn = Callable[[], bool]


@runtime_checkable
class EvalPlane(Protocol):
    """A surface that scores candidate k values, possibly many at once."""

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        """Score each k in ``ks``; returns scores aligned with the input."""
        ...

    def evaluate_one(self, k: int, should_abort: AbortFn | None = None) -> float:
        """Score a single k (scalar drivers; ``should_abort`` per §III-D)."""
        ...


class ScalarEvalPlane:
    """Adapter: a scalar ``evaluate(k)`` callable as an ``EvalPlane``.

    Detects once whether the callable accepts the §III-D ``should_abort``
    kwarg and forwards it only then, preserving the historical contract of
    ``ThreadPoolScheduler.run``.
    """

    def __init__(self, fn: Callable[..., float]):
        self.fn = fn
        self.accepts_abort = False
        try:
            self.accepts_abort = "should_abort" in inspect.signature(fn).parameters
        except (TypeError, ValueError):
            pass

    def evaluate_one(self, k: int, should_abort: AbortFn | None = None) -> float:
        # forward only a real callback: passing should_abort=None would
        # override a callable default the evaluator polls unconditionally
        if should_abort is not None and self.accepts_abort:
            return float(self.fn(k, should_abort=should_abort))
        return float(self.fn(k))

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        return [self.evaluate_one(k) for k in ks]


class _BatchOnlyAdapter:
    """Gives batch-only planes the scalar entry point the drivers expect."""

    def __init__(self, plane):
        self.plane = plane

    def evaluate_one(self, k: int, should_abort: AbortFn | None = None) -> float:
        # A black-box batch plane exposes no chunk boundary to poll
        # mid-fit, but the §III-D callback must not be silently dropped:
        # poll it before dispatching so a k pruned while queued never pays
        # for its fit at all (NaN is a void score — no threshold selects
        # it, so prune bounds and k_optimal are untouched). Planes with a
        # resumable fit implement ``evaluate_one`` themselves and poll at
        # every chunk boundary instead.
        if should_abort is not None and should_abort():
            return float("nan")
        return float(self.plane.evaluate_batch([k])[0])

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        return self.plane.evaluate_batch(ks)

    @property
    def last_lane_utilization(self):
        return getattr(self.plane, "last_lane_utilization", None)


def as_eval_plane(evaluate) -> EvalPlane:
    """Coerce a scalar callable or an EvalPlane-shaped object to EvalPlane."""
    if hasattr(evaluate, "evaluate_batch"):
        if hasattr(evaluate, "evaluate_one"):
            return evaluate
        return _BatchOnlyAdapter(evaluate)
    if callable(evaluate):
        return ScalarEvalPlane(evaluate)
    raise TypeError(f"cannot use {type(evaluate).__name__} as an evaluation plane")


@dataclasses.dataclass
class Wave:
    """One dispatched frontier: the ks sent together and their scores."""

    index: int
    ks: list[int]
    scores: list[float]
    lo_bound: float  # prune bounds after folding this wave's scores
    hi_bound: float


class WavefrontScheduler:
    """Batched Binary Bleed: evaluate frontiers of live midpoints as waves.

    Walks the same binary tree over ``space.ks`` as Algorithm 1, but
    breadth-first: the midpoints of all currently-live index intervals are
    independent (none is an ancestor of another), so they are dispatched to
    the plane as one ``evaluate_batch`` call. All returned scores are folded
    into the shared ``BleedState``, subtrees falling outside the updated
    bounds are dropped, and the next wave is the midpoints of the surviving
    children. Wave w holds at most 2^w entries, so a full run issues at most
    ceil(log2(|K|))+1 batch dispatches instead of one per visited k.

    Compared to the serial driver this may evaluate ks a just-landed wave
    would have pruned (same trade as the paper's multi-resource runs — a
    wave is "resources" executing concurrently), so visits form a superset
    of the serial schedule's but remain a subset of the pre-order worklist,
    and pruning soundness (pruned ks cannot be optimal) keeps ``k_optimal``
    identical for threshold-separable score shapes.

    ``max_wave`` caps the number of ks per dispatch (e.g. device memory);
    chunks of one wave re-check the prune state between dispatches, highest
    k first (``bleed_up_first``) since for the max-k objective high
    selecting ks prune the most.
    """

    def __init__(
        self,
        space: SearchSpace,
        max_wave: int | None = None,
        bleed_up_first: bool = True,
        tracer=None,
        metrics=None,
    ):
        if max_wave is not None and max_wave < 1:
            raise ValueError("max_wave must be >= 1")
        self.space = space
        self.max_wave = max_wave
        self.bleed_up_first = bleed_up_first
        self.waves: list[Wave] = []
        self._tracer = tracer
        self._metrics = metrics

    def run(self, evaluate, state=None) -> SearchResult:
        from .bleed import BleedState  # lazy: bleed sits above this module

        tracer = self._tracer if self._tracer is not None else get_tracer()
        metrics = self._metrics if self._metrics is not None else get_metrics()
        plane = as_eval_plane(evaluate)
        # tell capacity-aware planes the dispatch bound so their batch
        # padding (a compile-reuse optimization) never exceeds it; assign
        # unconditionally so a reused plane doesn't keep a stale cap
        if hasattr(plane, "dispatch_cap"):
            plane.dispatch_cap = self.max_wave
        space = self.space
        ks = space.ks
        state = state if state is not None else BleedState(space, tracer=tracer, metrics=metrics)
        self.waves = []
        wave_idx = 0
        intervals: list[tuple[int, int]] = [(0, len(ks))]  # [lo, hi) index spans

        while intervals:
            live = []
            for lo, hi in intervals:
                if lo >= hi:
                    continue
                if state.interval_alive(ks[lo], ks[hi - 1]):
                    live.append((lo, hi))
                else:
                    state.skip_interval(ks[lo], ks[hi - 1], hi - lo)
            mids = [lo + (hi - lo) // 2 for lo, hi in live]
            pending = []
            for m in mids:
                if state.should_visit(ks[m]):
                    pending.append(ks[m])
                else:
                    state.skip(ks[m])
            pending.sort(reverse=self.bleed_up_first)
            step = self.max_wave if self.max_wave is not None else max(len(pending), 1)
            for start in range(0, len(pending), step):
                # re-filter: earlier chunks of this wave may have pruned these
                chunk = []
                for k in pending[start : start + step]:
                    if state.should_visit(k):
                        chunk.append(k)
                    else:
                        state.skip(k, reason="pruned_by_chunk")
                if not chunk:
                    continue
                with tracer.span("wave", track="wavefront", wave=wave_idx, size=len(chunk),
                                 k_lo=min(chunk), k_hi=max(chunk)):
                    scores = plane.evaluate_batch(chunk)
                if len(scores) != len(chunk):
                    raise ValueError(
                        f"evaluate_batch returned {len(scores)} scores for {len(chunk)} ks"
                    )
                metrics.observe("wave_size", len(chunk))
                # mesh-sharded planes report real/dispatched lanes of the
                # dispatch they just ran; surface it as a live gauge next to
                # the wave_size histogram
                util = getattr(plane, "last_lane_utilization", None)
                if util is not None:
                    metrics.set_gauge("lane_utilization", float(util))
                with tracer.span("publish", track="wavefront", wave=wave_idx):
                    for k, score in zip(chunk, scores):
                        state.record(k, float(score), resource=wave_idx)
                self.waves.append(
                    Wave(wave_idx, list(chunk), [float(s) for s in scores],
                         state.lo_bound, state.hi_bound)
                )
                wave_idx += 1
            # descend: children of every live interval (midpoint evaluated or
            # not — Alg 1 recurses regardless); dead ones are filtered above.
            nxt: list[tuple[int, int]] = []
            for (lo, hi), mid in zip(live, mids):
                halves = ((mid + 1, hi), (lo, mid)) if self.bleed_up_first else ((lo, mid), (mid + 1, hi))
                nxt.extend(h for h in halves if h[0] < h[1])
            intervals = nxt

        return state.result()

    @property
    def n_dispatches(self) -> int:
        """Number of batch dispatches issued by the last ``run``."""
        return len(self.waves)


class ElasticWavefrontScheduler:
    """Continuous-batching Binary Bleed: a stream of fit-chunks, not waves.

    Drives an *elastic plane* (``submit(k)`` / ``cancel(k)`` / ``tick()`` /
    ``idle`` / ``inflight_ks()`` — e.g. ``repro.factorization.planes.
    NMFkElasticPlane``) instead of ``evaluate_batch``. The unit of
    scheduling is one chunk of MU sweeps across every occupied lane; the
    driver's loop between chunks is where Binary Bleed happens:

      1. **admit** — drain ks from the pre-order traversal worklist into
         the plane's lane queue while the refill policy has room, skipping
         ks the current bounds already prune (the candidate stream of the
         wavefront executor is exactly this worklist — descent happens
         regardless of scores, pruning only filters — so elastic refill
         preserves Alg 1/3/4 visit semantics);
      2. **tick** — one chunk dispatch; converged/budget-exhausted lanes
         retire inside the plane and completed ks come back scored;
      3. **record** — fold scores into ``BleedState``, updating bounds;
      4. **evict** — cancel in-flight ks the new bounds prune (§III-D
         mid-fit abort, charged to ``ks_aborted`` / ``sweeps_saved``).

    Like the wave executor, concurrency makes visits a superset of the
    serial schedule but a subset of the pre-order worklist; pruning
    soundness keeps ``k_optimal`` identical for threshold-separable score
    shapes. Every k ends either recorded (scored) or skipped (pruned at
    admission or evicted), so visited + skipped == |K|.
    """

    def __init__(self, space: SearchSpace, refill=None, tracer=None, metrics=None):
        self.space = space
        self.refill = refill
        self._tracer = tracer
        self._metrics = metrics
        self.n_ticks = 0

    def run(self, plane, state=None) -> SearchResult:
        from .bleed import BleedState  # lazy: bleed sits above this module
        from .scheduler import LaneRefillPolicy

        tracer = self._tracer if self._tracer is not None else get_tracer()
        metrics = self._metrics if self._metrics is not None else get_metrics()
        policy = self.refill if self.refill is not None else LaneRefillPolicy()
        space = self.space
        state = state if state is not None else BleedState(space, tracer=tracer, metrics=metrics)
        worklist = list(policy.worklist(space.ks))
        pos = 0
        self.n_ticks = 0

        while True:
            # 1. admit: refill the lane queue from the live worklist prefix
            while pos < len(worklist) and policy.admit(plane):
                k = worklist[pos]
                pos += 1
                if state.should_visit(k):
                    plane.submit(k)
                else:
                    state.skip(k)
            if plane.idle:
                if pos >= len(worklist):
                    break
                # a refill policy must not starve an idle plane: force one
                # admission so the loop always progresses
                k = worklist[pos]
                pos += 1
                if state.should_visit(k):
                    plane.submit(k)
                else:
                    state.skip(k)
                continue
            # 2. tick: one chunk across all occupied lanes
            with tracer.span("tick", track="wavefront", tick=self.n_ticks):
                finished = plane.tick()
            self.n_ticks += 1
            occ = getattr(plane, "last_lane_occupancy", None)
            if occ is not None:
                metrics.set_gauge("lane_utilization", float(occ))
            # 3. record: fold completed scores into the prune bounds
            with tracer.span("publish", track="wavefront", tick=self.n_ticks - 1):
                for k, score in finished:
                    state.record(k, float(score), resource=self.n_ticks - 1)
            # 4. evict: ks the updated bounds prune stop paying mid-fit
            for k in sorted(plane.inflight_ks(), reverse=True):
                if not state.should_visit(k) and plane.cancel(k):
                    metrics.inc("ks_aborted")
                    tracer.event("abort", track="wavefront", k=k)
                    state.skip(k, reason="aborted")

        return state.result()

    @property
    def n_dispatches(self) -> int:
        """Number of chunk dispatches issued by the last ``run``."""
        return self.n_ticks


__all__ = [
    "EvalPlane",
    "ScalarEvalPlane",
    "WavefrontScheduler",
    "ElasticWavefrontScheduler",
    "Wave",
    "as_eval_plane",
]
