"""Persistent jit compile cache wiring for repeated k-searches.

Shape bucketing (``repro.factorization.batching.bucket_batch``) caps the
number of distinct compiled ``(batch, k_pad)`` shapes *within* one search;
this module makes those few compilations survive *across* processes: with
``jax_compilation_cache_dir`` set, XLA executables are written to disk and
the next search over the same data shape deserializes instead of
recompiling — the dominant cold-start cost of the batched/sharded
executors.

JAX only persists entries above built-in time/size thresholds by default
(tuned for multi-minute TPU compiles); ``enable_persistent_cache`` lowers
both to zero so the second-long CPU/GPU compiles of the wavefront planes
are cached too.

This is deliberately config-only — no jax device state is touched at
import time, so ``repro.core`` stays importable before XLA_FLAGS tricks
like ``--xla_force_host_platform_device_count``.
"""
from __future__ import annotations

import os


def enable_persistent_cache(
    cache_dir: str,
    min_compile_time_secs: float = 0.0,
    min_entry_size_bytes: int = -1,
) -> bool:
    """Point jax's persistent compilation cache at ``cache_dir``.

    Returns True if the cache was configured, False if this jax build does
    not expose the config knobs (older/stripped builds) — callers treat
    False as "run without a cache", never as an error. Call before the
    first jit dispatch; entries compiled earlier are not retro-cached.
    """
    import jax

    os.makedirs(cache_dir, exist_ok=True)
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # persist everything: the default thresholds skip sub-second compiles
        jax.config.update("jax_persistent_cache_min_compile_time_secs", min_compile_time_secs)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", min_entry_size_bytes)
    except (AttributeError, ValueError):  # pragma: no cover - jax without the knobs
        return False
    return True


def cache_entry_count(cache_dir: str) -> int:
    """Number of serialized executables currently in ``cache_dir``."""
    try:
        return sum(1 for e in os.scandir(cache_dir) if e.is_file())
    except FileNotFoundError:
        return 0


__all__ = ["enable_persistent_cache", "cache_entry_count"]
