"""Chunking of the k list across resources (paper Algorithm 2 + Table II).

Algorithm 2 ("Skip Mod Resource Count") deals k values round-robin by their
rank in ascending order: element with sorted-rank r goes to resource
``r mod num_resources`` (input list order is preserved within each chunk).
Every resource then holds a spread of low *and* high k values, so a prune
broadcast from one resource still leaves useful work on all others — the
failure mode of contiguous block chunking (Table II T1/T3) is one resource
idling after a prune while another grinds an un-prunable block.

Rank-mod (rather than position-in-list mod) reproduces the paper's Table II
for both T2 (chunk after traversal sort) and T4 (chunk before), and stays
load-balanced for arbitrary, non-contiguous k lists.

Four composition orders from Table II, for the ablation benchmark:

  T1: traversal-sort whole K, then block-chunk
  T2: traversal-sort whole K, then skip-mod chunk
  T3: block-chunk, then traversal-sort each chunk       (paper: least optimal)
  T4: skip-mod chunk, then traversal-sort each chunk    (paper: best; the
      scheduler default, used in paper Figs 2-6)
"""
from __future__ import annotations

from typing import Sequence

from .traversal import Order, traversal_sort


def chunk_skip_mod(ks: Sequence[int], num_resources: int) -> list[list[int]]:
    """Algorithm 2: deal ks round-robin (by ascending rank) over resources."""
    if num_resources < 1:
        raise ValueError("num_resources must be >= 1")
    rank = {k: r for r, k in enumerate(sorted(set(ks)))}
    chunks: list[list[int]] = [[] for _ in range(num_resources)]
    for k in ks:  # preserve input order within chunks
        chunks[rank[k] % num_resources].append(k)
    return chunks


def chunk_block(ks: Sequence[int], num_resources: int) -> list[list[int]]:
    """Contiguous block split ("Chunk Ks by Resource Count", T1/T3)."""
    if num_resources < 1:
        raise ValueError("num_resources must be >= 1")
    ks = list(ks)
    n = len(ks)
    base, rem = divmod(n, num_resources)
    chunks, start = [], 0
    for r in range(num_resources):
        size = base + (1 if r < rem else 0)
        chunks.append(ks[start : start + size])
        start += size
    return chunks


def plan_worklists(
    ks: Sequence[int],
    num_resources: int,
    order: Order = "pre",
    strategy: str = "T4",
) -> list[list[int]]:
    """Produce per-resource visit-ordered worklists per Table II strategy."""
    ks = sorted(ks)
    if strategy == "T1":
        return chunk_block(traversal_sort(ks, order), num_resources)
    if strategy == "T2":
        return chunk_skip_mod(traversal_sort(ks, order), num_resources)
    if strategy == "T3":
        return [traversal_sort(sorted(c), order) for c in chunk_block(ks, num_resources)]
    if strategy == "T4":
        return [traversal_sort(sorted(c), order) for c in chunk_skip_mod(ks, num_resources)]
    raise ValueError(f"unknown strategy {strategy!r} (want T1|T2|T3|T4)")


def rebalance(
    remaining: Sequence[int],
    num_resources: int,
    order: Order = "pre",
) -> list[list[int]]:
    """Elastic re-chunk of *unvisited* k values over surviving resources.

    Used on resource failure/join: Alg 2 is stateless over any k set, so
    rebalancing is just re-running T4 on the remaining pool. Deterministic.
    """
    return plan_worklists(sorted(set(remaining)), num_resources, order=order, strategy="T4")
