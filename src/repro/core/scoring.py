"""Cluster-quality scoring in pure JAX (jit-compatible, Pallas-accelerable).

The paper pairs Binary Bleed with:
  * silhouette score (maximize) — NMFk / RESCALk stability scoring,
  * Davies-Bouldin index (minimize) — K-Means.

Both need all-pairs distances — the Tscorer hot spot. ``pairwise_sq_dists``
dispatches to the Pallas kernel (`repro.kernels.pairwise_dist`) when
``use_kernel=True`` and shapes are tile-aligned; the jnp fallback is the
oracle the kernel is tested against.

§III-D synthetic score models (square wave / Laplacian peak) are included:
they drive the property tests and the visit-count benchmarks without paying
for real fits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


def pairwise_sq_dists(x: Array, y: Array | None = None, use_kernel: bool = False) -> Array:
    """Squared euclidean distances between rows of x (n,d) and y (m,d)."""
    y = x if y is None else y
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.pairwise_sq_dists(x, y)
    # ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y  with clamping for fp error
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xx + yy - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("num_clusters", "use_kernel"))
def silhouette_score(x: Array, labels: Array, num_clusters: int, use_kernel: bool = False) -> Array:
    """Mean silhouette coefficient, vectorized over clusters.

    Matches sklearn semantics: singleton clusters get s(i)=0; requires
    ``num_clusters`` static for fixed shapes under jit.
    """
    n = x.shape[0]
    d = jnp.sqrt(pairwise_sq_dists(x, use_kernel=use_kernel))
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=x.dtype)  # (n, k)
    sizes = jnp.sum(onehot, axis=0)  # (k,)
    # sum of distances from each point to each cluster: (n, k)
    dist_sums = d @ onehot
    own = onehot[jnp.arange(n), labels]  # ones; keeps grads sane
    del own
    own_size = sizes[labels]  # (n,)
    # a(i): mean intra-cluster distance excluding self
    a = dist_sums[jnp.arange(n), labels] / jnp.maximum(own_size - 1.0, 1.0)
    # b(i): min over other clusters of mean distance
    mean_to = dist_sums / jnp.maximum(sizes[None, :], 1.0)  # (n, k)
    mask_own = jax.nn.one_hot(labels, num_clusters, dtype=bool)
    empty = (sizes[None, :] == 0)
    big = jnp.asarray(jnp.inf, x.dtype)
    b = jnp.min(jnp.where(mask_own | empty, big, mean_to), axis=1)
    s = (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-12)
    s = jnp.where(own_size <= 1.0, 0.0, s)  # singleton convention
    return jnp.mean(s)


@functools.partial(jax.jit, static_argnames=("num_clusters",))
def davies_bouldin_score(x: Array, labels: Array, num_clusters: int) -> Array:
    """Davies-Bouldin index (lower = better separated clusters)."""
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=x.dtype)  # (n, k)
    sizes = jnp.maximum(jnp.sum(onehot, axis=0), 1.0)  # (k,)
    centroids = (onehot.T @ x) / sizes[:, None]  # (k, d)
    # intra-cluster scatter S_i: mean distance to centroid
    d_to_c = jnp.sqrt(pairwise_sq_dists(x, centroids))  # (n, k)
    own_d = jnp.sum(d_to_c * onehot, axis=1)  # (n,)
    scatter = (onehot.T @ own_d) / sizes  # (k,)
    # centroid separation M_ij
    m = jnp.sqrt(pairwise_sq_dists(centroids))  # (k, k)
    r = (scatter[:, None] + scatter[None, :]) / jnp.maximum(m, 1e-12)
    r = jnp.where(jnp.eye(num_clusters, dtype=bool), -jnp.inf, r)
    # empty clusters contribute nothing
    present = jnp.sum(onehot, axis=0) > 0
    r = jnp.where(present[None, :], r, -jnp.inf)
    worst = jnp.max(r, axis=1)
    worst = jnp.where(present, worst, 0.0)
    return jnp.sum(worst) / jnp.maximum(jnp.sum(present), 1.0)


# --------------------------------------------------------------------------
# §III-D synthetic score distributions
# --------------------------------------------------------------------------
def square_wave_score(k: int | Array, k_optimal: int, hi: float = 1.0, lo: float = 0.0) -> Array:
    """S(k) = (sgn(k0 - k) + 1)/2 scaled to [lo, hi] — ideal silhouette shape.

    Follows the paper: +1 for k < k0+1 (i.e. k <= k0), -1 after — high
    scores up to and including the optimum, a cliff after it.
    """
    k = jnp.asarray(k)
    s01 = (jnp.sign(k_optimal - k + 0.5) + 1.0) / 2.0
    return lo + (hi - lo) * s01


def laplacian_score(k: int | Array, k_optimal: int, width: float = 2.0, hi: float = 1.0) -> Array:
    """Worst-case §III-D distribution: a Laplacian peak at k0.

    Only k≈k0 crosses a high threshold; Binary Bleed degrades gracefully to
    at-most-linear visits.
    """
    k = jnp.asarray(k, jnp.float32)
    return hi * jnp.exp(-jnp.abs(k - k_optimal) / width)


def noisy(score_fn, key: jax.Array, sigma: float = 0.02):
    """Wrap a synthetic score with Gaussian observation noise."""

    def f(k):
        sub = jax.random.fold_in(key, int(k))
        return score_fn(k) + sigma * jax.random.normal(sub, ())

    return f
