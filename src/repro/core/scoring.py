"""Cluster-quality scoring in pure JAX (jit-compatible, Pallas-accelerable).

The paper pairs Binary Bleed with:
  * silhouette score (maximize) — NMFk / RESCALk stability scoring,
  * Davies-Bouldin index (minimize) — K-Means.

Both reduce all-pairs distances — the Tscorer hot spot. The silhouette only
ever consumes the (n, n) distance matrix through one contraction,
``dist_sums = sqrt(D2) @ onehot`` — so ``cluster_dist_sums`` computes the
(n, k) sums directly and dispatches across three tiers:

  1. **dense jnp** — materialize sqrt(D2) and contract. Fastest for small n
     (one fused XLA GEMM chain), O(n^2) memory; selected when the per-lane
     distance block fits ``_DENSE_MAX_ELEMENTS``.
  2. **blocked jnp** — ``lax.map`` over row blocks: each (block_rows, n)
     distance strip is built, contracted to (block_rows, k), and freed.
     Peak footprint O(block_rows * n) instead of O(n^2); serves large n on
     any backend and every non-tile-aligned shape.
  3. **Pallas** (``use_kernel=True``) — the fused streaming kernel
     (`repro.kernels.silhouette_sums`): each (bn, bm) distance tile lives
     only in VMEM, sqrt applied in-register, accumulated straight into the
     (bn, k) sums. HBM output traffic O(n*k); D never exists in HBM.

``pairwise_sq_dists`` likewise dispatches to the Pallas distance kernel
(`repro.kernels.pairwise_dist`) when ``use_kernel=True``; the jnp fallbacks
are the oracles the kernels are tested against.

§III-D synthetic score models (square wave / Laplacian peak) are included:
they drive the property tests and the visit-count benchmarks without paying
for real fits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


def pairwise_sq_dists(x: Array, y: Array | None = None, use_kernel: bool = False) -> Array:
    """Squared euclidean distances between rows of x (..., n, d) and y (..., m, d).

    Leading batch axes broadcast; with ``use_kernel=True`` a 2-D input goes
    to the tiled Pallas kernel and a 3-D input to its batched (leading-axis)
    entry point, so the Pallas path stays usable from batched scorers.
    """
    y = x if y is None else y
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        # the kernels take equal-rank operands; materialize the broadcast
        # the jnp path would do implicitly for mixed 2-D/3-D inputs
        if x.ndim == 2 and y.ndim == 3:
            x = jnp.broadcast_to(x, (y.shape[0],) + x.shape)
        elif x.ndim == 3 and y.ndim == 2:
            y = jnp.broadcast_to(y, (x.shape[0],) + y.shape)
        if x.ndim == 2:
            return kernel_ops.pairwise_sq_dists(x, y)
        if x.ndim == 3:
            return kernel_ops.pairwise_sq_dists_batched(x, y)
        raise ValueError(f"kernel path supports 2-D or 3-D inputs, got {x.ndim}-D")
    # ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y  with clamping for fp error
    xx = jnp.sum(x * x, axis=-1)[..., :, None]
    yy = jnp.sum(y * y, axis=-1)[..., None, :]
    d2 = xx + yy - 2.0 * jnp.matmul(x, jnp.swapaxes(y, -1, -2))
    return jnp.maximum(d2, 0.0)


# Dense-tier ceiling: largest per-lane (n, m) distance block the dense path
# may materialize (fp32 elements; 2048^2 = 16 MiB). Above it, row-blocking.
_DENSE_MAX_ELEMENTS = 2048 * 2048
_DEFAULT_BLOCK_ROWS = 512


def _cluster_dist_sums_blocked(x: Array, onehot: Array, block_rows: int) -> Array:
    """Tier 2: row-blocked ``sqrt(pairwise) @ onehot`` via ``lax.map``.

    x (..., n, d), onehot (..., n, k) — each (block_rows, n) distance strip
    is contracted to (block_rows, k) and discarded, so the peak footprint is
    O(block_rows * n) regardless of n.
    """
    n = x.shape[-2]
    n_blocks = -(-n // block_rows)
    pad = n_blocks * block_rows - n
    widths = [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)]
    xp = jnp.pad(x, widths)

    def one_block(i):
        xi = jax.lax.dynamic_slice_in_dim(xp, i * block_rows, block_rows, axis=-2)
        strip = jnp.sqrt(pairwise_sq_dists(xi, x))  # (..., block_rows, n)
        return jnp.matmul(strip, onehot)

    res = jax.lax.map(one_block, jnp.arange(n_blocks))  # (n_blocks, ..., block_rows, k)
    res = jnp.moveaxis(res, 0, -3)  # (..., n_blocks, block_rows, k)
    res = res.reshape(res.shape[:-3] + (n_blocks * block_rows, onehot.shape[-1]))
    return res[..., :n, :]


def cluster_dist_sums(
    x: Array,
    onehot: Array,
    use_kernel: bool = False,
    block_rows: int | None = None,
) -> Array:
    """(…, n, k) sums of sqrt distances from every point to every cluster.

    ``out[..., i, c] = sum_j sqrt(||x_i - x_j||^2) * onehot[..., j, c]`` —
    the only form in which the silhouette consumes the distance matrix.
    Masked points carry zero one-hot rows and contract to nothing.

    Dispatch (see module docstring): ``use_kernel=True`` routes 2-D inputs
    to the fused streaming Pallas kernel and 3-D inputs to its batched
    entry; otherwise small problems take the dense jnp tier and anything
    past ``_DENSE_MAX_ELEMENTS`` per lane the blocked tier. Passing
    ``block_rows`` forces the blocked tier at that strip height.
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        # the kernels take equal-rank operands; the jnp tiers instead keep
        # an unbatched x unbatched so one distance pass serves all lanes
        if x.ndim == onehot.ndim - 1:
            x = jnp.broadcast_to(x, onehot.shape[:-2] + x.shape[-2:])
        elif onehot.ndim == x.ndim - 1:
            onehot = jnp.broadcast_to(onehot, x.shape[:-2] + onehot.shape[-2:])
        if x.ndim == 2:
            return kernel_ops.silhouette_dist_sums(x, onehot)
        if x.ndim == 3:
            return kernel_ops.silhouette_dist_sums_batched(x, onehot)
        raise ValueError(f"kernel path supports 2-D or 3-D inputs, got {x.ndim}-D")
    n = x.shape[-2]
    if block_rows is None and n * n <= _DENSE_MAX_ELEMENTS:
        return jnp.matmul(jnp.sqrt(pairwise_sq_dists(x)), onehot)
    return _cluster_dist_sums_blocked(x, onehot, block_rows or _DEFAULT_BLOCK_ROWS)


@functools.partial(jax.jit, static_argnames=("num_clusters", "use_kernel"))
def silhouette_score(x: Array, labels: Array, num_clusters: int, use_kernel: bool = False) -> Array:
    """Mean silhouette coefficient, vectorized over clusters.

    Matches sklearn semantics: singleton clusters get s(i)=0; requires
    ``num_clusters`` static for fixed shapes under jit.
    """
    n = x.shape[0]
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=x.dtype)  # (n, k)
    sizes = jnp.sum(onehot, axis=0)  # (k,)
    # sum of distances from each point to each cluster: (n, k) — streamed,
    # the (n, n) distance matrix is never materialized past the dense tier
    dist_sums = cluster_dist_sums(x, onehot, use_kernel=use_kernel)
    own_size = sizes[labels]  # (n,)
    # a(i): mean intra-cluster distance excluding self
    a = dist_sums[jnp.arange(n), labels] / jnp.maximum(own_size - 1.0, 1.0)
    # b(i): min over other clusters of mean distance
    mean_to = dist_sums / jnp.maximum(sizes[None, :], 1.0)  # (n, k)
    mask_own = jax.nn.one_hot(labels, num_clusters, dtype=bool)
    empty = (sizes[None, :] == 0)
    big = jnp.asarray(jnp.inf, x.dtype)
    b = jnp.min(jnp.where(mask_own | empty, big, mean_to), axis=1)
    s = (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-12)
    s = jnp.where(own_size <= 1.0, 0.0, s)  # singleton convention
    return jnp.mean(s)


@functools.partial(jax.jit, static_argnames=("num_clusters",))
def davies_bouldin_score(x: Array, labels: Array, num_clusters: int) -> Array:
    """Davies-Bouldin index (lower = better separated clusters)."""
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=x.dtype)  # (n, k)
    sizes = jnp.maximum(jnp.sum(onehot, axis=0), 1.0)  # (k,)
    centroids = (onehot.T @ x) / sizes[:, None]  # (k, d)
    # intra-cluster scatter S_i: mean distance to centroid
    d_to_c = jnp.sqrt(pairwise_sq_dists(x, centroids))  # (n, k)
    own_d = jnp.sum(d_to_c * onehot, axis=1)  # (n,)
    scatter = (onehot.T @ own_d) / sizes  # (k,)
    # centroid separation M_ij
    m = jnp.sqrt(pairwise_sq_dists(centroids))  # (k, k)
    r = (scatter[:, None] + scatter[None, :]) / jnp.maximum(m, 1e-12)
    r = jnp.where(jnp.eye(num_clusters, dtype=bool), -jnp.inf, r)
    # empty clusters contribute nothing
    present = jnp.sum(onehot, axis=0) > 0
    r = jnp.where(present[None, :], r, -jnp.inf)
    worst = jnp.max(r, axis=1)
    worst = jnp.where(present, worst, 0.0)
    return jnp.sum(worst) / jnp.maximum(jnp.sum(present), 1.0)


# --------------------------------------------------------------------------
# Masked variants — padded batched fits (one vmapped fit serves many k's)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("num_clusters", "use_kernel"))
def silhouette_samples_masked(
    x: Array,
    labels: Array,
    num_clusters: int,
    point_mask: Array | None = None,
    use_kernel: bool = False,
) -> Array:
    """Per-point silhouette values; padding points and clusters are zeroed.

    Shapes are axis-agnostic over optional leading batch dims: x (..., n, d),
    labels (..., n) int, point_mask (..., n) bool (False = padding point,
    excluded from every cluster; its s(i) is 0). Clusters that end up empty
    after masking — in particular the padded slots >= k_eff of a mask-padded
    fit — never appear in b(i) and contribute nothing. Returns s (..., n);
    both the mean score and NMFk's per-cluster min reduce from this one
    streamed dist-sums pass.
    """
    mask = (
        jnp.ones(x.shape[:-1], bool)
        if point_mask is None
        else (jnp.zeros(x.shape[:-1], bool) | point_mask)
    )
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=x.dtype) * mask[..., None]
    sizes = jnp.sum(onehot, axis=-2)  # (..., k) — active members only
    # masked one-hot rows are zero, so the streaming contraction is exact:
    # padding points contribute nothing without ever masking distances
    dist_sums = cluster_dist_sums(x, onehot, use_kernel=use_kernel)  # (..., n, k)
    own_size = jnp.take_along_axis(sizes[..., None, :], labels[..., None], axis=-1)[..., 0]
    own_sum = jnp.take_along_axis(dist_sums, labels[..., None], axis=-1)[..., 0]
    a = own_sum / jnp.maximum(own_size - 1.0, 1.0)
    mean_to = dist_sums / jnp.maximum(sizes[..., None, :], 1.0)
    mask_own = jax.nn.one_hot(labels, num_clusters, dtype=bool)
    empty = sizes[..., None, :] == 0  # includes every padded cluster slot
    big = jnp.asarray(jnp.inf, x.dtype)
    b = jnp.min(jnp.where(mask_own | empty, big, mean_to), axis=-1)
    s = (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-12)
    s = jnp.where(own_size <= 1.0, 0.0, s)  # singleton convention
    return jnp.where(mask, s, 0.0)


@functools.partial(jax.jit, static_argnames=("num_clusters", "use_kernel"))
def silhouette_score_masked(
    x: Array,
    labels: Array,
    num_clusters: int,
    point_mask: Array | None = None,
    use_kernel: bool = False,
) -> Array:
    """Mean silhouette over active points only; padded clusters are ignored.

    The score at (k_eff, k_pad) equals ``silhouette_score`` at k_eff; see
    ``silhouette_samples_masked`` for the shape/mask contract.
    """
    s = silhouette_samples_masked(x, labels, num_clusters, point_mask, use_kernel)
    if point_mask is None:
        return jnp.mean(s, axis=-1)
    n_active = jnp.sum(jnp.zeros(x.shape[:-1], bool) | point_mask, axis=-1)
    return jnp.sum(s, axis=-1) / jnp.maximum(n_active, 1.0)


@functools.partial(jax.jit, static_argnames=("num_clusters",))
def davies_bouldin_score_masked(
    x: Array,
    labels: Array,
    num_clusters: int,
    cluster_mask: Array | None = None,
    point_mask: Array | None = None,
) -> Array:
    """Davies-Bouldin index ignoring padded clusters (and padding points).

    Axis-agnostic over leading batch dims like ``silhouette_score_masked``.
    ``cluster_mask`` (..., k) marks the active centroid slots of a
    mask-padded fit (slots >= k_eff are False); inactive or empty clusters
    are excluded from both the pairwise-worst max and the final mean.
    """
    mask = (
        jnp.ones(x.shape[:-1], bool) if point_mask is None else jnp.broadcast_to(point_mask, x.shape[:-1])
    )
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=x.dtype) * mask[..., None]
    if cluster_mask is not None:
        onehot = onehot * cluster_mask[..., None, :].astype(x.dtype)
    counts = jnp.sum(onehot, axis=-2)  # (..., k)
    sizes = jnp.maximum(counts, 1.0)
    centroids = jnp.matmul(jnp.swapaxes(onehot, -1, -2), x) / sizes[..., None]
    d_to_c = jnp.sqrt(pairwise_sq_dists(x, centroids))  # (..., n, k)
    own_d = jnp.sum(d_to_c * onehot, axis=-1)  # (..., n)
    scatter = jnp.matmul(jnp.swapaxes(onehot, -1, -2), own_d[..., None])[..., 0] / sizes
    m = jnp.sqrt(pairwise_sq_dists(centroids))  # (..., k, k)
    r = (scatter[..., :, None] + scatter[..., None, :]) / jnp.maximum(m, 1e-12)
    r = jnp.where(jnp.eye(num_clusters, dtype=bool), -jnp.inf, r)
    present = counts > 0
    if cluster_mask is not None:
        present = present & cluster_mask
    r = jnp.where(present[..., None, :], r, -jnp.inf)
    worst = jnp.max(r, axis=-1)
    worst = jnp.where(present, worst, 0.0)
    return jnp.sum(worst, axis=-1) / jnp.maximum(jnp.sum(present, axis=-1), 1.0)


# --------------------------------------------------------------------------
# §III-D synthetic score distributions
# --------------------------------------------------------------------------
def square_wave_score(k: int | Array, k_optimal: int, hi: float = 1.0, lo: float = 0.0) -> Array:
    """S(k) = (sgn(k0 - k) + 1)/2 scaled to [lo, hi] — ideal silhouette shape.

    Follows the paper: +1 for k < k0+1 (i.e. k <= k0), -1 after — high
    scores up to and including the optimum, a cliff after it.
    """
    k = jnp.asarray(k)
    s01 = (jnp.sign(k_optimal - k + 0.5) + 1.0) / 2.0
    return lo + (hi - lo) * s01


def laplacian_score(k: int | Array, k_optimal: int, width: float = 2.0, hi: float = 1.0) -> Array:
    """Worst-case §III-D distribution: a Laplacian peak at k0.

    Only k≈k0 crosses a high threshold; Binary Bleed degrades gracefully to
    at-most-linear visits.
    """
    k = jnp.asarray(k, jnp.float32)
    return hi * jnp.exp(-jnp.abs(k - k_optimal) / width)


def noisy(score_fn, key: jax.Array, sigma: float = 0.02):
    """Wrap a synthetic score with Gaussian observation noise."""

    def f(k):
        sub = jax.random.fold_in(key, int(k))
        return score_fn(k) + sigma * jax.random.normal(sub, ())

    return f
