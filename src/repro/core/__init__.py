"""Binary Bleed core: the paper's contribution as a composable library."""
from .api import (  # noqa: F401
    ElasticWavefrontScheduler,
    EvalPlane,
    LaneRefillPolicy,
    Mode,
    ScalarEvalPlane,
    ScheduleTrace,
    SearchResult,
    SearchSpace,
    SimulatedScheduler,
    ThreadPoolScheduler,
    WavefrontScheduler,
    as_eval_plane,
    binary_bleed_recursive,
    binary_bleed_search,
    binary_bleed_worklist,
    grid_search,
    make_space,
    standard_search,
)
from .evalplane import Wave  # noqa: F401
from .chunking import chunk_block, chunk_skip_mod, plan_worklists, rebalance  # noqa: F401
from .compile_cache import cache_entry_count, enable_persistent_cache  # noqa: F401
from .coordinator import Bounds, FileCoordinator, InProcessCoordinator  # noqa: F401
from .scheduler import ResourceEvent  # noqa: F401
from .scoring import (  # noqa: F401
    cluster_dist_sums,
    davies_bouldin_score,
    davies_bouldin_score_masked,
    laplacian_score,
    pairwise_sq_dists,
    silhouette_samples_masked,
    silhouette_score,
    silhouette_score_masked,
    square_wave_score,
)
from .traversal import traversal_sort  # noqa: F401
