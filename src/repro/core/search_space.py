"""K search-space definition for Binary Bleed.

The paper searches an ordered set ``K = {k_min, ..., k_max}`` for

    k_optimal = max { k in K : S(f(k, D)) >= T }        (maximization)
    k_optimal = max { k in K : S(f(k, D)) <= T }        (minimization)

with an optional early-stop bound ``U`` (§III-C): once any score crosses U
in the "bad" direction, all larger k are pruned.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence


class Mode(str, enum.Enum):
    """Optimization direction of the scoring function.

    MAXIMIZE: silhouette-style — score is high (>= T) up to k_opt, low after.
    MINIMIZE: Davies-Bouldin-style — score is low (<= T) up to k_opt.
    """

    MAXIMIZE = "maximize"
    MINIMIZE = "minimize"


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """An ordered, duplicate-free k search space with thresholds.

    Attributes:
      ks: strictly increasing candidate k values.
      select_threshold: T — a score on the "good" side of T marks k as a
        candidate optimum and prunes all smaller unvisited k (Vanilla).
      stop_threshold: U — a score on the "bad" side of U prunes all larger
        unvisited k (Early Stop). ``None`` disables early stop.
      mode: maximize (silhouette) or minimize (Davies-Bouldin).
    """

    ks: tuple[int, ...]
    select_threshold: float
    stop_threshold: float | None = None
    mode: Mode = Mode.MAXIMIZE

    def __post_init__(self) -> None:
        ks = tuple(int(k) for k in self.ks)
        if len(ks) == 0:
            raise ValueError("search space must be non-empty")
        if any(b <= a for a, b in zip(ks, ks[1:])):
            raise ValueError("ks must be strictly increasing")
        object.__setattr__(self, "ks", ks)
        if self.stop_threshold is not None:
            # stop bound must be on the "bad" side of the select bound.
            if self.mode == Mode.MAXIMIZE and self.stop_threshold > self.select_threshold:
                raise ValueError("stop_threshold must be <= select_threshold for maximize")
            if self.mode == Mode.MINIMIZE and self.stop_threshold < self.select_threshold:
                raise ValueError("stop_threshold must be >= select_threshold for minimize")

    @classmethod
    def from_range(
        cls,
        k_min: int,
        k_max: int,
        select_threshold: float,
        stop_threshold: float | None = None,
        mode: Mode = Mode.MAXIMIZE,
        step: int = 1,
    ) -> "SearchSpace":
        return cls(tuple(range(k_min, k_max + 1, step)), select_threshold, stop_threshold, mode)

    def __len__(self) -> int:
        return len(self.ks)

    # --- threshold predicates -------------------------------------------------
    def selects(self, score: float) -> bool:
        """True if `score` crosses the select threshold T (prunes lower k)."""
        if self.mode == Mode.MAXIMIZE:
            return score >= self.select_threshold
        return score <= self.select_threshold

    def stops(self, score: float) -> bool:
        """True if `score` crosses the stop threshold U (prunes higher k)."""
        if self.stop_threshold is None:
            return False
        if self.mode == Mode.MAXIMIZE:
            return score <= self.stop_threshold
        return score >= self.stop_threshold


@dataclasses.dataclass
class VisitRecord:
    """One (k, score) evaluation — an element of the paper's ``ranks_seen``."""

    k: int
    score: float
    resource: int = 0
    pruned_lower: bool = False
    pruned_upper: bool = False
    wall_order: int = -1  # global completion order across resources


@dataclasses.dataclass
class SearchResult:
    """Outcome of a Binary Bleed run.

    ``visits`` preserves evaluation order; ``k_optimal`` is None when no k
    crossed the select threshold (the paper returns "not found" — callers
    fall back to argmax/argmin of the seen scores if they want a best-effort
    answer).
    """

    k_optimal: int | None
    visits: list[VisitRecord]
    n_candidates: int

    @property
    def n_visited(self) -> int:
        return len(self.visits)

    @property
    def visit_fraction(self) -> float:
        return self.n_visited / max(1, self.n_candidates)

    @property
    def visited_ks(self) -> list[int]:
        return [v.k for v in self.visits]

    def best_effort_k(self, mode: Mode = Mode.MAXIMIZE) -> int | None:
        """k_optimal, falling back to extremal seen score when nothing selected."""
        if self.k_optimal is not None:
            return self.k_optimal
        if not self.visits:
            return None
        key = (lambda v: v.score) if mode == Mode.MAXIMIZE else (lambda v: -v.score)
        return max(self.visits, key=key).k


def validate_ks(ks: Sequence[int]) -> tuple[int, ...]:
    out = tuple(sorted(set(int(k) for k in ks)))
    if not out:
        raise ValueError("empty k list")
    return out
