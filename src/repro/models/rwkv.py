"""RWKV-6 (Finch) time-mix + channel-mix — attention-free mixer with
data-dependent decay (the v6 hallmark: w_t is a low-rank function of x_t).

Per head (k-dim = v-dim = head_size), state S (hs, hs):
    out_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(w0 + lora(x_t)))

Training scans over time; decode carries (x_prev, S). Channel-mix is the
RWKV squared-ReLU FFN (the config's d_ff).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, RWKVConfig
from .layers import Axes, dense_init

Array = jax.Array
PyTree = Any


class RWKVState(NamedTuple):
    x_prev_tm: Array  # (B, d) last input to time-mix (token shift)
    x_prev_cm: Array  # (B, d) last input to channel-mix
    s: Array  # (B, H, hs, hs) wkv state, fp32


def _dims(cfg: ArchConfig) -> tuple[int, int, int]:
    r: RWKVConfig = cfg.rwkv or RWKVConfig()
    hs = r.head_size
    nh = cfg.d_model // hs
    return nh, hs, r.decay_lora


def rwkv_time_mix_init(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> PyTree:
    d = cfg.d_model
    nh, hs, lora = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "mix_r": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_k": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_v": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_g": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_w": 0.5 * jnp.ones((d,), jnp.float32),
        "wr": dense_init(ks[0], (d, d), d, dtype),
        "wk": dense_init(ks[1], (d, d), d, dtype),
        "wv": dense_init(ks[2], (d, d), d, dtype),
        "wg": dense_init(ks[3], (d, d), d, dtype),
        "wo": dense_init(ks[4], (d, d), d, dtype),
        # data-dependent decay: w0 + tanh(x W_a) W_b
        "w0": -6.0 * jnp.ones((d,), jnp.float32),
        "w_a": dense_init(ks[5], (d, lora), d, jnp.float32),
        "w_b": dense_init(ks[6], (lora, d), lora, jnp.float32),
        "u": jnp.zeros((nh, hs), jnp.float32),  # per-head bonus
        "ln_scale": jnp.ones((nh, hs), jnp.float32),  # per-head output norm
    }


def rwkv_time_mix_specs(ax: Axes, cfg: ArchConfig) -> PyTree:
    d = cfg.d_model
    da = ax.dim_axis(d)
    return {
        "mix_r": P(None), "mix_k": P(None), "mix_v": P(None), "mix_g": P(None), "mix_w": P(None),
        "wr": P(None, da), "wk": P(None, da), "wv": P(None, da), "wg": P(None, da),
        "wo": P(da, None),
        "w0": P(None), "w_a": P(None, None), "w_b": P(None, None),
        "u": P(ax.dim_axis(_dims(cfg)[0]), None),
        "ln_scale": P(ax.dim_axis(_dims(cfg)[0]), None),
    }


def _mix(x: Array, x_prev: Array, mu: Array) -> Array:
    return x + (x_prev - x) * mu.astype(x.dtype)


def _decay(params: PyTree, xw: Array) -> Array:
    """w_t in (0,1): exp(-exp(w0 + tanh(x W_a) W_b)), fp32."""
    lo = jnp.tanh(xw.astype(jnp.float32) @ params["w_a"]) @ params["w_b"]
    return jnp.exp(-jnp.exp(params["w0"] + lo))


def _head_norm(params: PyTree, out: Array, eps: float = 1e-5) -> Array:
    """Per-head RMS norm of the wkv output. out: (..., H, hs), fp32."""
    var = jnp.mean(out * out, axis=-1, keepdims=True)
    return out * jax.lax.rsqrt(var + eps) * params["ln_scale"]


_WKV_CHUNK = 16  # tokens per parallel chunk (C x C score blocks)
# fp32 safety floor for the per-chunk cumulative log decay. The factored
# r~/k~ form is exact while |per-chunk log-decay span| < 25 nats, i.e.
# per-step decay >= e^{-25/16} ~ 0.21 — covers trained RWKV-6 ranges; the
# state recurrence across chunks multiplies by e^{lw_last} <= 1 and is
# unconditionally stable. Pairs separated by > 25 nats of decay contribute
# < e^-25 in exact math.
_LOG_DECAY_CLAMP = -25.0


def _wkv_naive(rh, kh, vh, wh, u, s0):
    """Reference recurrence: one lax.scan step per token (O(L) HBM round
    trips on the state — the memory-bound baseline)."""

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # each (B, H, hs)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, hs, hs)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, out

    inps = tuple(jnp.moveaxis(a, 1, 0) for a in (rh, kh, vh, wh))
    s, outs = jax.lax.scan(step, s0, inps)
    return s, jnp.moveaxis(outs, 0, 1)  # (B, L, H, hs)


def _wkv_chunked(rh, kh, vh, wh, u, s0, chunk: int = _WKV_CHUNK):
    """Chunk-parallel WKV (§Perf iteration 1): the state crosses HBM once
    per chunk instead of once per token; within-chunk work is C x C matmuls.

    With lw_i = sum_{j<=i} log w_j (cumulative log decay inside the chunk):
      out_i   = (r_i * e^{lw_{i-1}}) S_prev
              + sum_{j<i} (r_i . (k_j * e^{lw_{i-1}-lw_j})) v_j
              + (r_i . (u * k_i)) v_i
      S_next  = e^{lw_last} S_prev + sum_j (k_j e^{lw_last - lw_j}) v_j^T
    Exponents are <= 0 for j <= i-1, and lw is clamped so the k-side
    e^{-lw_j} factor stays inside fp32 (standard GLA/FLA chunking).
    """
    b, l, nh, hs = rh.shape
    assert l % chunk == 0, (l, chunk)
    n = l // chunk
    resh = lambda a: jnp.moveaxis(a.reshape(b, n, chunk, nh, hs), 1, 0)
    rc, kc, vc, wc = resh(rh), resh(kh), resh(vh), resh(wh)  # (n, B, C, H, hs)

    def one_chunk(s, inp):
        r, k, v, w = inp  # (B, C, H, hs)
        lw = jnp.cumsum(jnp.log(jnp.maximum(w, 1e-38)), axis=1)  # (B, C, H, hs)
        lw = jnp.maximum(lw, _LOG_DECAY_CLAMP)
        lw_prev = jnp.pad(lw, ((0, 0), (1, 0), (0, 0), (0, 0)))[:, :-1]  # lw_{i-1}
        lw_last = lw[:, -1:]  # (B, 1, H, hs)
        r_dec = r * jnp.exp(lw_prev)  # r~_i
        k_dec = k * jnp.exp(-lw)  # k~_j
        # inter-chunk contribution + intra-chunk lower-triangular attention
        out_state = jnp.einsum("bchk,bhkv->bchv", r_dec, s)
        scores = jnp.einsum("bihk,bjhk->bhij", r_dec, k_dec)  # (B, H, C, C)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
        scores = scores * tri[None, None]
        out_intra = jnp.einsum("bhij,bjhv->bihv", scores, v)
        out_diag = jnp.einsum("bchk,bchk->bch", r, u[None, None] * k)[..., None] * v
        out = out_state + out_intra + out_diag
        # state update
        k_fwd = k * jnp.exp(lw_last - lw)  # k_j e^{lw_last - lw_j}
        s_new = jnp.exp(lw_last[:, 0])[..., None] * s + jnp.einsum(
            "bchk,bchv->bhkv", k_fwd, v
        )
        return s_new, out

    s, outs = jax.lax.scan(one_chunk, s0, (rc, kc, vc, wc))
    return s, jnp.moveaxis(outs, 0, 1).reshape(b, l, nh, hs)


def rwkv_time_mix(
    params: PyTree, x: Array, cfg: ArchConfig, ax: Axes, chunked: bool = True
) -> Array:
    """x: (B, L, d) -> (B, L, d). Chunk-parallel WKV when L allows."""
    b, l, d = x.shape
    nh, hs, _ = _dims(cfg)
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]  # token shift
    r = _mix(x, x_prev, params["mix_r"]) @ params["wr"]
    k = _mix(x, x_prev, params["mix_k"]) @ params["wk"]
    v = _mix(x, x_prev, params["mix_v"]) @ params["wv"]
    g = jax.nn.silu(_mix(x, x_prev, params["mix_g"]) @ params["wg"])
    w = _decay(params, _mix(x, x_prev, params["mix_w"]))  # (B, L, d) fp32

    rh = r.reshape(b, l, nh, hs).astype(jnp.float32)
    kh = k.reshape(b, l, nh, hs).astype(jnp.float32)
    vh = v.reshape(b, l, nh, hs).astype(jnp.float32)
    wh = w.reshape(b, l, nh, hs)
    u = params["u"]
    s0 = jnp.zeros((b, nh, hs, hs), jnp.float32)
    if chunked and l % _WKV_CHUNK == 0:
        _, out = _wkv_chunked(rh, kh, vh, wh, u, s0)
    else:
        _, out = _wkv_naive(rh, kh, vh, wh, u, s0)
    out = _head_norm(params, out).reshape(b, l, d).astype(x.dtype)
    return (out * g) @ params["wo"]


def rwkv_channel_mix_init(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> PyTree:
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix_k": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_r": 0.5 * jnp.ones((d,), jnp.float32),
        "wk": dense_init(ks[0], (d, dff), d, dtype),
        "wv": dense_init(ks[1], (dff, d), dff, dtype),
        "wr": dense_init(ks[2], (d, d), d, dtype),
    }


def rwkv_channel_mix_specs(ax: Axes, cfg: ArchConfig) -> PyTree:
    ff = ax.dim_axis(cfg.d_ff)
    return {
        "mix_k": P(None), "mix_r": P(None),
        "wk": P(None, ff), "wv": P(ff, None), "wr": P(None, ax.dim_axis(cfg.d_model)),
    }


def rwkv_channel_mix(params: PyTree, x: Array, x_prev: Array | None = None) -> Array:
    """Squared-ReLU FFN with token shift. x: (B, L, d)."""
    if x_prev is None:
        xp = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xp = jnp.concatenate([x_prev[:, None], x], axis=1)[:, :-1]
    k = _mix(x, xp, params["mix_k"]) @ params["wk"]
    kv = (jax.nn.relu(k) ** 2) @ params["wv"]
    r = jax.nn.sigmoid(_mix(x, xp, params["mix_r"]) @ params["wr"])
    return r * kv


def rwkv_state_init(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> RWKVState:
    nh, hs, _ = _dims(cfg)
    d = cfg.d_model
    return RWKVState(
        x_prev_tm=jnp.zeros((batch, d), dtype),
        x_prev_cm=jnp.zeros((batch, d), dtype),
        s=jnp.zeros((batch, nh, hs, hs), jnp.float32),
    )


def rwkv_state_specs(cfg: ArchConfig, ax: Axes) -> RWKVState:
    nh, _, _ = _dims(cfg)
    return RWKVState(
        x_prev_tm=P(ax.b, None),
        x_prev_cm=P(ax.b, None),
        s=P(ax.b, ax.dim_axis(nh), None, None),
    )


def rwkv_decode(
    tm_params: PyTree,
    cm_params: PyTree,
    x_tm: Array,  # (B, 1, d) input to time-mix (post-norm)
    state: RWKVState,
    cfg: ArchConfig,
) -> tuple[Array, Array, RWKVState]:
    """Single-token step. Returns (time_mix_out, new_x_prev_tm_consumed_flag)
    — channel-mix is applied by the caller with state.x_prev_cm."""
    b, _, d = x_tm.shape
    nh, hs, _ = _dims(cfg)
    xp = state.x_prev_tm[:, None]
    r = _mix(x_tm, xp, tm_params["mix_r"]) @ tm_params["wr"]
    k = _mix(x_tm, xp, tm_params["mix_k"]) @ tm_params["wk"]
    v = _mix(x_tm, xp, tm_params["mix_v"]) @ tm_params["wv"]
    g = jax.nn.silu(_mix(x_tm, xp, tm_params["mix_g"]) @ tm_params["wg"])
    w = _decay(tm_params, _mix(x_tm, xp, tm_params["mix_w"]))[:, 0].reshape(b, nh, hs)
    r_t = r[:, 0].reshape(b, nh, hs).astype(jnp.float32)
    k_t = k[:, 0].reshape(b, nh, hs).astype(jnp.float32)
    v_t = v[:, 0].reshape(b, nh, hs).astype(jnp.float32)
    kv = k_t[..., :, None] * v_t[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", r_t, state.s + tm_params["u"][..., None] * kv)
    s_new = w[..., None] * state.s + kv
    out = _head_norm(tm_params, out[:, None]).reshape(b, 1, d).astype(x_tm.dtype)
    y = (out * g) @ tm_params["wo"]
    new_state = RWKVState(x_prev_tm=x_tm[:, 0], x_prev_cm=state.x_prev_cm, s=s_new)
    return y, new_state
