"""Mamba selective-SSM block (Jamba's mixer) — train scan + decode step.

Recurrence (per channel c, state dim n):
    h_t = exp(Δ_t A) ⊙ h_{t-1} + Δ_t B_t x_t
    y_t = C_t · h_t + D x_t
Training runs a `lax.scan` over time (sequential HLO loop; the chunked
parallel form is a §Perf candidate); decode carries (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, SSMConfig
from .layers import Axes, dense_init

Array = jax.Array
PyTree = Any


class MambaState(NamedTuple):
    conv: Array  # (B, d_conv-1, d_in) — trailing inputs for the causal conv
    ssm: Array  # (B, d_in, d_state)


def _dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    s: SSMConfig = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, s.d_state, s.d_conv, dt_rank


def mamba_init(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> PyTree:
    d = cfg.d_model
    d_in, d_state, d_conv, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), d, dtype),
        "conv_w": dense_init(ks[1], (d_conv, d_in), d_conv, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * d_state), d_in, dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), dt_rank, dtype),
        "dt_bias": jnp.log(jnp.expm1(0.01)) * jnp.ones((d_in,), jnp.float32),
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_in, d_state))),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d), d_in, dtype),
    }


def mamba_specs(ax: Axes, cfg: ArchConfig) -> PyTree:
    d_in, d_state, _, dt_rank = _dims(cfg)
    di = ax.dim_axis(d_in)
    return {
        "in_proj": P(None, ax.dim_axis(2 * d_in)),
        "conv_w": P(None, di),
        "conv_b": P(di),
        "x_proj": P(di, None),
        "dt_proj": P(None, di),
        "dt_bias": P(di),
        "a_log": P(di, None),
        "d_skip": P(di),
        "out_proj": P(di, None),
    }


def _conv_causal(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv. x: (B, L, d_in), w: (d_conv, d_in)."""
    d_conv = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(d_conv))
    return out + b


_SSM_CHUNK = 16  # tokens per scan step (state stays VMEM-resident within)


def _ssm_scan(xs: Array, dt: Array, b: Array, c: Array, a: Array, h0: Array):
    """xs,(dt): (B, L, d_in); b,c: (B, L, n); a: (d_in, n); h0: (B, d_in, n).

    Chunk-unrolled selective scan (§Perf, jamba): Mamba-1's decay is
    per-(channel, state) so the RWKV-style matmul chunking doesn't apply,
    but unrolling C tokens inside each scan body keeps the (B, d_in, n)
    state out of HBM for C-1 of every C steps and loads the per-token
    tensors one chunk at a time. Falls back to token-steps when C∤L.
    """
    l = xs.shape[1]
    chunk = _SSM_CHUNK if l % _SSM_CHUNK == 0 else 1

    def token_update(h, x_t, dt_t, b_t, c_t):
        da = jnp.exp(dt_t[..., None] * a)  # (B, d_in, n)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    if chunk == 1:
        def step(h, inp):
            x_t, dt_t, b_t, c_t = inp
            return token_update(h, x_t, dt_t, b_t, c_t)

        inps = tuple(jnp.moveaxis(t, 1, 0) for t in (xs, dt, b, c))
        h, ys = jax.lax.scan(step, h0, inps)
        return h, jnp.moveaxis(ys, 0, 1)

    n_chunks = l // chunk
    resh = lambda t: jnp.moveaxis(
        t.reshape(t.shape[0], n_chunks, chunk, *t.shape[2:]), 1, 0
    )
    inps = tuple(resh(t) for t in (xs, dt, b, c))

    def chunk_step(h, inp):
        x_c, dt_c, b_c, c_c = inp  # (B, C, ...)
        ys = []
        for j in range(chunk):  # unrolled: h never round-trips HBM here
            h, y = token_update(h, x_c[:, j], dt_c[:, j], b_c[:, j], c_c[:, j])
            ys.append(y)
        return h, jnp.stack(ys, axis=1)  # (B, C, d_in)

    h, ys = jax.lax.scan(chunk_step, h0, inps)
    ys = jnp.moveaxis(ys, 0, 1).reshape(xs.shape[0], l, -1)
    return h, ys


def _project(params: PyTree, u: Array, cfg: ArchConfig):
    d_in, d_state, _, dt_rank = _dims(cfg)
    xz = u @ params["in_proj"]  # (B, L, 2*d_in)
    x, z = xz[..., :d_in], xz[..., d_in:]
    return x, z, d_in, d_state, dt_rank


def _ssm_params(params: PyTree, x: Array, d_state: int, dt_rank: int):
    proj = x @ params["x_proj"]  # (B, L, dt_rank + 2n)
    dt = jax.nn.softplus(
        proj[..., :dt_rank] @ params["dt_proj"] + params["dt_bias"]
    ).astype(jnp.float32)
    b = proj[..., dt_rank : dt_rank + d_state].astype(jnp.float32)
    c = proj[..., dt_rank + d_state :].astype(jnp.float32)
    a = -jnp.exp(params["a_log"])
    return dt, b, c, a


def mamba_forward(params: PyTree, u: Array, cfg: ArchConfig, ax: Axes) -> Array:
    """u: (B, L, d) -> (B, L, d)."""
    x, z, d_in, d_state, dt_rank = _project(params, u, cfg)
    x = jax.nn.silu(_conv_causal(x, params["conv_w"], params["conv_b"]))
    dt, b, c, a = _ssm_params(params, x, d_state, dt_rank)
    h0 = jnp.zeros((u.shape[0], d_in, d_state), jnp.float32)
    _, y = _ssm_scan(x.astype(jnp.float32), dt, b, c, a, h0)
    y = y + params["d_skip"] * x.astype(jnp.float32)
    y = (y.astype(u.dtype)) * jax.nn.silu(z)
    return y @ params["out_proj"]


def mamba_state_init(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> MambaState:
    d_in, d_state, d_conv, _ = _dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, d_conv - 1, d_in), dtype),
        ssm=jnp.zeros((batch, d_in, d_state), jnp.float32),
    )


def mamba_state_specs(cfg: ArchConfig, ax: Axes) -> MambaState:
    d_in, _, _, _ = _dims(cfg)
    di = ax.dim_axis(d_in)
    return MambaState(conv=P(ax.b, None, di), ssm=P(ax.b, di, None))


def mamba_decode(
    params: PyTree, u: Array, state: MambaState, cfg: ArchConfig, ax: Axes
) -> tuple[Array, MambaState]:
    """u: (B, 1, d) single-token step."""
    x, z, d_in, d_state, dt_rank = _project(params, u, cfg)
    # conv over [state.conv ‖ x]
    window = jnp.concatenate([state.conv, x], axis=1)  # (B, d_conv, d_in)
    xc = jnp.einsum("bld,ld->bd", window, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]  # (B, 1, d_in)
    dt, b, c, a = _ssm_params(params, xc, d_state, dt_rank)
    da = jnp.exp(dt[:, 0, :, None] * a)  # (B, d_in, n)
    h = da * state.ssm + (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * b[:, 0][:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c[:, 0]) + params["d_skip"] * xc[:, 0].astype(jnp.float32)
    y = (y[:, None, :].astype(u.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, MambaState(conv=window[:, 1:], ssm=h)
