"""Shared layers + sharding helpers for the LM substrate.

Sharding philosophy: params carry explicit PartitionSpec trees (built next
to their initializers), activations get ``with_sharding_constraint`` at
block boundaries. Logical axes:

  batch  -> ('pod', 'data') on the multi-pod mesh, ('data',) single-pod
  model  -> 'model' (TP / EP / head sharding)

``dim_axis(size)`` returns 'model' only when `size` divides evenly over the
model-axis length — GQA kv-heads (8 < 16) fall back to head-dim sharding or
replication rather than producing invalid uneven shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class Axes:
    """Logical->physical axis environment for one mesh."""

    batch: tuple[str, ...] = ("data",)  # ('pod','data') on multi-pod; () = replicated
    model: str = "model"
    model_size: int = 16  # devices along the model axis

    @property
    def b(self):
        """Batch PartitionSpec entry: tuple of axes, or None when the batch
        cannot shard (e.g. long_500k's global_batch=1)."""
        return self.batch if self.batch else None

    def dim_axis(self, size: int) -> str | None:
        """'model' iff the dim shards evenly, else None (replicate)."""
        return self.model if size % self.model_size == 0 else None

    def pick(self, *dims: int) -> int:
        """Index of the first dim that shards evenly; -1 if none."""
        for i, d in enumerate(dims):
            if d % self.model_size == 0:
                return i
        return -1


def shard(x: Array, spec: P) -> Array:
    """with_sharding_constraint that is a no-op outside jit/mesh contexts."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# -----------------------------------------------------------------------------
# initializers — all fan-in scaled normal, deterministic per (key, path)
# -----------------------------------------------------------------------------
def dense_init(key: Array, shape: tuple[int, ...], fan_in: int | None = None, dtype=jnp.bfloat16) -> Array:
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = fan_in**-0.5
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def embed_init(key: Array, vocab: int, d: int, dtype=jnp.bfloat16) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


# -----------------------------------------------------------------------------
# RMSNorm
# -----------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_specs() -> PyTree:
    return {"scale": P(None)}


def rmsnorm(params: PyTree, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


# -----------------------------------------------------------------------------
# SwiGLU MLP (Megatron column/row TP pair)
# -----------------------------------------------------------------------------
def mlp_init(key: Array, d: int, d_ff: int, dtype=jnp.bfloat16) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, d_ff), d, dtype),
        "w_up": dense_init(k2, (d, d_ff), d, dtype),
        "w_down": dense_init(k3, (d_ff, d), d_ff, dtype),
    }


def mlp_specs(ax: Axes, d: int, d_ff: int, seq_sharded: bool = False) -> PyTree:
    if seq_sharded:
        # sequence-parallel residual: tokens shard over 'model', weights
        # replicate (zero MLP collectives; right trade for small-d_ff archs
        # whose heads don't divide the model axis)
        return {"w_gate": P(None, None), "w_up": P(None, None), "w_down": P(None, None)}
    ff = ax.dim_axis(d_ff)
    return {
        "w_gate": P(None, ff),  # column parallel
        "w_up": P(None, ff),
        "w_down": P(ff, None),  # row parallel (psum after)
    }


def mlp(params: PyTree, x: Array, ax: Axes, seq_sharded: bool = False) -> Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shard(h, P(ax.b, ax.model, None) if seq_sharded else P(ax.b, None, ax.model))
    return h @ params["w_down"]


# -----------------------------------------------------------------------------
# Embedding / LM head
# -----------------------------------------------------------------------------
def embedding_init(key: Array, vocab: int, d: int, tie: bool, dtype=jnp.bfloat16) -> PyTree:
    k1, k2 = jax.random.split(key)
    p = {"table": embed_init(k1, vocab, d, dtype)}
    if not tie:
        p["lm_head"] = dense_init(k2, (d, vocab), d, dtype)
    return p


def embedding_specs(ax: Axes, vocab: int, tie: bool) -> PyTree:
    v = ax.dim_axis(vocab)
    p = {"table": P(v, None)}
    if not tie:
        p["lm_head"] = P(None, v)
    return p


def embed_tokens(params: PyTree, tokens: Array) -> Array:
    return params["table"][tokens]


def lm_logits(params: PyTree, x: Array, ax: Axes) -> Array:
    """(B, L, d) -> (B, L, V), fp32 logits, vocab-sharded."""
    if "lm_head" in params:
        logits = x @ params["lm_head"].astype(x.dtype)
    else:
        logits = x @ params["table"].astype(x.dtype).T
    return shard(logits.astype(jnp.float32), P(ax.b, None, ax.model))


def cross_entropy(logits: Array, labels: Array, ignore_id: int = -1) -> Array:
    """Mean token NLL; labels == ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
