"""Attention mixers: GQA (+RoPE, sliding window, QKV bias) and DeepSeek-V2
MLA (multi-head latent attention) — train/prefill and KV-cache decode paths.

TPU adaptations:
  * train/prefill can route through the Pallas flash-attention kernel
    (``use_flash``); default is the einsum path (XLA fuses well, and the
    kernel is validated against it).
  * decode caches: GQA keeps (k, v) ring-buffered to the attention window
    when one exists (O(window) memory at 500k contexts); MLA caches the
    576-dim latent (c_kv ‖ k_rope) and uses the absorbed-matmul decode —
    attention reads scale with kv_lora_rank, not heads×head_dim.
  * sharding: heads shard over 'model' when divisible by the axis size,
    else head_dim, else replicated (`Axes.dim_axis`).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from .layers import Axes, dense_init, rmsnorm, rmsnorm_init, rmsnorm_specs, shard

Array = jax.Array
PyTree = Any
_NEG = -1e30


# -----------------------------------------------------------------------------
# RoPE
# -----------------------------------------------------------------------------
def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (..., L, H, hd) or (..., L, hd); positions: (L,) or (B, L)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # x is (..., L, H, hd): insert the head axis so (L, half) -> (L, 1, half)
    cos, sin = jnp.expand_dims(cos, -2), jnp.expand_dims(sin, -2)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _sdpa(
    q: Array,  # (B, Lq, H, hd)
    k: Array,  # (B, Lk, Hk, hd)
    v: Array,  # (B, Lk, Hk, hd)
    causal: bool,
    window: int | None,
    q_offset: Array | int = 0,
    kv_len: Array | None = None,
    scale: float | None = None,
) -> Array:
    """Dense scaled-dot-product attention with GQA + causal/window/len masks.

    ``q_offset``: absolute position of q row 0 (decode: current pos).
    ``kv_len``: number of valid kv entries (decode with ring/full cache).
    """
    b, lq, h, hd = q.shape
    lk, hk = k.shape[1], k.shape[2]
    group = h // hk
    scale = float(scale if scale is not None else hd**-0.5)
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, jnp.repeat(k.astype(jnp.float32), group, axis=2))
    q_idx = jnp.asarray(q_offset) + jnp.arange(lq)[:, None]
    k_idx = jnp.arange(lk)[None, :]
    # additive (lq, lk) bias instead of a boolean mask select: the broadcast
    # to (b, h, lq, lk) stays fused — a materialized pred mask at that shape
    # is GBs and gets hoisted into loop carries by XLA.
    bias = jnp.zeros((lq, lk), jnp.float32)
    if causal:
        bias = jnp.where(k_idx <= q_idx, bias, _NEG)
    if window is not None:
        bias = jnp.where(k_idx > q_idx - window, bias, _NEG)
    if kv_len is not None:
        bias = jnp.where(k_idx < kv_len, bias, _NEG)
    s = s + bias[None, None]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, jnp.repeat(v.astype(jnp.float32), group, axis=2))
    return out.astype(q.dtype)


_CHUNK_THRESHOLD = 2048  # above this, full (Lq, Lk) scores would blow HBM
_Q_CHUNK = 1024


def _sdpa_auto(
    q: Array, k: Array, v: Array, causal: bool, window: int | None, scale: float | None = None
) -> Array:
    """Dense attention for short seqs; q-chunked (scanned) for long ones.

    The chunked form bounds live score memory to (B, H, q_chunk, Lk) per
    step — the XLA analogue of flash attention's outer loop (the Pallas
    kernel is the TPU fast path; this is the portable lowering the dry-run
    compiles). One full pass over K/V per chunk keeps HBM traffic linear.
    """
    b, lq, h, hd = q.shape
    if lq <= _CHUNK_THRESHOLD:
        return _sdpa(q, k, v, causal=causal, window=window, scale=scale)
    qc = _Q_CHUNK
    assert lq % qc == 0, (lq, qc)
    n = lq // qc
    xs = jnp.moveaxis(q.reshape(b, n, qc, h, hd), 1, 0)  # (n, b, qc, h, hd)

    def step(i, q_blk):
        out_blk = _sdpa(q_blk, k, v, causal=causal, window=window, q_offset=i * qc, scale=scale)
        return i + 1, out_blk

    # checkpoint each chunk: backward recomputes that chunk's scores instead
    # of stashing (n, b, h, qc, lk) fp32 probability tensors across chunks
    step = jax.checkpoint(step, prevent_cse=False)
    _, outs = jax.lax.scan(step, jnp.asarray(0, jnp.int32), xs)
    # out head dim follows v (MLA: qk dim 192 vs v dim 128)
    return jnp.moveaxis(outs, 0, 1).reshape(b, lq, h, v.shape[-1])


# =============================================================================
# GQA
# =============================================================================
class KVCache(NamedTuple):
    k: Array  # (B, S, Hk, hd) — S = min(seq, window) ring buffer
    v: Array


def gqa_init(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> PyTree:
    d, h, hk = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim()
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": dense_init(ks[1], (d, hk, hd), d, dtype),
        "wv": dense_init(ks[2], (d, hk, hd), d, dtype),
        "wo": dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((hk, hd), dtype)
        p["bv"] = jnp.zeros((hk, hd), dtype)
    return p


def gqa_specs(ax: Axes, cfg: ArchConfig) -> PyTree:
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    hq_ax = ax.dim_axis(h)
    kv_ax = ax.dim_axis(hk)
    # Weights shard on the HEAD axis only. Sharding head_dim instead would
    # make every score einsum contract a sharded dim -> an all-reduce of the
    # (b, h, lq, lk) score tensor per layer (observed: 1.9 GB/layer for
    # qwen2). When heads don't divide the axis, replicate — attention
    # weights are small and FSDP widening still shards d_model.
    p = {
        "wq": P(None, hq_ax, None),
        "wk": P(None, kv_ax, None),
        "wv": P(None, kv_ax, None),
        "wo": P(hq_ax, None, None),
    }
    if cfg.qkv_bias:
        p["bq"] = P(hq_ax, None)
        p["bk"] = P(kv_ax, None)
        p["bv"] = P(kv_ax, None)
    return p


def _project_qkv(params: PyTree, x: Array, cfg: ArchConfig):
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return q, k, v


def gqa_forward(
    params: PyTree,
    x: Array,  # (B, L, d)
    cfg: ArchConfig,
    ax: Axes,
    positions: Array | None = None,
    use_flash: bool = False,
) -> Array:
    b, l, d = x.shape
    h = cfg.num_heads
    positions = jnp.arange(l) if positions is None else positions
    q, k, v = _project_qkv(params, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # Head-parallel when heads divide the model axis. Otherwise SEQUENCE-
    # parallel (§Perf iteration: qwen2's 14 heads don't divide 16; without
    # this every model-axis device replicated the full attention — 16x
    # wasted score FLOPs/HBM). Query rows shard over 'model'; k/v replicate
    # (they're GQA-small); causal masking uses absolute indices so the
    # chunked scan stays correct under a sharded L.
    head_ax = ax.dim_axis(h)
    seq_parallel = head_ax is None and ax.model_size > 1 and l % ax.model_size == 0
    if seq_parallel:
        q = shard(q, P(ax.b, ax.model, None, None))
        # K/V must see the full sequence: gather THEM (GQA-small) rather
        # than letting GSPMD gather the full residual stream
        k = shard(k, P(ax.b, None, None, None))
        v = shard(v, P(ax.b, None, None, None))
    else:
        q = shard(q, P(ax.b, None, head_ax, None))
    if use_flash:
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=True, window=cfg.window,
        ).transpose(0, 2, 1, 3)
    elif seq_parallel and l <= _CHUNK_THRESHOLD * 4:
        # L-sharding already bounds live scores to (b, l/axis, h, l) — skip
        # the q-chunk scan (its reshape would fight the sharded L axis)
        out = _sdpa(q, k, v, causal=True, window=cfg.window)
    else:
        out = _sdpa_auto(q, k, v, causal=True, window=cfg.window)
    if seq_parallel:
        out = shard(out, P(ax.b, ax.model, None, None))
    else:
        out = shard(out, P(ax.b, None, head_ax, None))
    return jnp.einsum("blhk,hkd->bld", out, params["wo"])


def gqa_cache_init(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> KVCache:
    s = min(seq_len, cfg.window) if cfg.window else seq_len
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    return KVCache(
        k=jnp.zeros((batch, s, hk, hd), dtype), v=jnp.zeros((batch, s, hk, hd), dtype)
    )


def gqa_cache_specs(cfg: ArchConfig, ax: Axes) -> KVCache:
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    kv_pick = ax.pick(hk, hd)
    spec = [None, None]
    if kv_pick >= 0:
        spec[kv_pick] = ax.model
    return KVCache(k=P(ax.b, None, *spec), v=P(ax.b, None, *spec))


def gqa_prefill(
    params: PyTree, x: Array, cfg: ArchConfig, ax: Axes, cache_len: int | None = None
) -> tuple[Array, KVCache]:
    """Full-sequence forward that also returns the (ring-windowed) cache.

    ``cache_len``: total decode capacity (>= l). Window archs get a ring
    buffer of min(window, cache_len) slots aligned to ``slot = pos % s`` —
    the same convention gqa_decode writes with.
    """
    b, l, _ = x.shape
    cache_len = cache_len or l
    positions = jnp.arange(l)
    q, k, v = _project_qkv(params, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = _sdpa_auto(q, k, v, causal=True, window=cfg.window)
    y = jnp.einsum("blhk,hkd->bld", out, params["wo"])
    if cfg.window is not None:
        s = min(cfg.window, cache_len)
        tail_k, tail_v = k[:, max(l - s, 0) :], v[:, max(l - s, 0) :]
        if l < s:  # pad up to ring size; slots >= l masked by kv_len
            pad = ((0, 0), (0, s - l), (0, 0), (0, 0))
            tail_k, tail_v = jnp.pad(tail_k, pad), jnp.pad(tail_v, pad)
            cache = KVCache(k=tail_k, v=tail_v)
        else:  # align ring: entry at absolute pos p lives in slot p % s
            shift = l % s
            cache = KVCache(k=jnp.roll(tail_k, shift, axis=1), v=jnp.roll(tail_v, shift, axis=1))
    else:
        pad = ((0, 0), (0, cache_len - l), (0, 0), (0, 0))
        cache = KVCache(k=jnp.pad(k, pad), v=jnp.pad(v, pad))
    return y, cache


def gqa_decode(
    params: PyTree,
    x: Array,  # (B, 1, d)
    cache: KVCache,
    pos: Array,  # scalar int32 — absolute position of this token
    cfg: ArchConfig,
    ax: Axes,
) -> tuple[Array, KVCache]:
    q, k_new, v_new = _project_qkv(params, x, cfg)
    posb = jnp.reshape(pos, (1,))
    q = rope(q, posb, cfg.rope_theta)
    k_new = rope(k_new, posb, cfg.rope_theta)
    s = cache.k.shape[1]
    slot = (pos % s) if cfg.window is not None else jnp.minimum(pos, s - 1)
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
    if cfg.window is not None:
        # ring buffer: every slot valid once pos+1 >= s; RoPE phases are
        # absolute so scores are position-correct without rotation.
        kv_len = jnp.minimum(pos + 1, s)
        out = _sdpa(q, k, v, causal=False, window=None, q_offset=pos, kv_len=kv_len)
    else:
        out = _sdpa(q, k, v, causal=False, window=None, q_offset=pos, kv_len=pos + 1)
    y = jnp.einsum("blhk,hkd->bld", out, params["wo"])
    return y, KVCache(k=k, v=v)


# =============================================================================
# MLA (DeepSeek-V2)
# =============================================================================
class MLACache(NamedTuple):
    ckv: Array  # (B, S, kv_lora + rope_dim): latent ‖ roped shared key


def mla_init(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> PyTree:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), d, dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h, qk_head), m.q_lora_rank, dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), d, dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank),
        "wkv_b": dense_init(
            ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim), m.kv_lora_rank, dtype
        ),
        "wo": dense_init(ks[4], (h, m.v_head_dim, d), h * m.v_head_dim, dtype),
    }


def mla_specs(ax: Axes, cfg: ArchConfig) -> PyTree:
    h = cfg.num_heads
    ha = ax.dim_axis(h)
    return {
        "wq_a": P(None, ax.dim_axis(cfg.mla.q_lora_rank)),
        "q_norm": rmsnorm_specs(),
        "wq_b": P(None, ha, None),
        "wkv_a": P(None, None),
        "kv_norm": rmsnorm_specs(),
        "wkv_b": P(None, ha, None),
        "wo": P(ha, None, None),
    }


def _mla_project(params: PyTree, x: Array, cfg: ArchConfig, positions: Array):
    m = cfg.mla
    nope, rdim = m.qk_nope_head_dim, m.qk_rope_head_dim
    q = rmsnorm(params["q_norm"], x @ params["wq_a"], cfg.norm_eps)
    q = jnp.einsum("blr,rhk->blhk", q, params["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    kv = x @ params["wkv_a"]  # (B, L, kv_lora + rdim)
    c_kv = rmsnorm(params["kv_norm"], kv[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = rope(kv[..., m.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(
    params: PyTree, x: Array, cfg: ArchConfig, ax: Axes, positions: Array | None = None
) -> Array:
    """Train/prefill: expand the latent and run standard MHA."""
    m = cfg.mla
    b, l, _ = x.shape
    positions = jnp.arange(l) if positions is None else positions
    q_nope, q_rope, c_kv, k_rope = _mla_project(params, x, cfg, positions)
    kvb = jnp.einsum("blr,rhk->blhk", c_kv, params["wkv_b"])
    k_nope, v = kvb[..., : m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim :]
    h = cfg.num_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (b, l, h, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    q = shard(q, P(ax.b, None, ax.dim_axis(h), None))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = _sdpa_auto(q, k, v, causal=True, window=None, scale=scale)
    out = shard(out, P(ax.b, None, ax.dim_axis(h), None))
    return jnp.einsum("blhv,hvd->bld", out, params["wo"])


def mla_cache_init(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> MLACache:
    m = cfg.mla
    return MLACache(ckv=jnp.zeros((batch, seq_len, m.kv_lora_rank + m.qk_rope_head_dim), dtype))


def mla_cache_specs(cfg: ArchConfig, ax: Axes) -> MLACache:
    width = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    return MLACache(ckv=P(ax.b, None, ax.dim_axis(width)))


def mla_prefill(
    params: PyTree, x: Array, cfg: ArchConfig, ax: Axes, cache_len: int | None = None
) -> tuple[Array, MLACache]:
    b, l, _ = x.shape
    cache_len = cache_len or l
    positions = jnp.arange(l)
    y = mla_forward(params, x, cfg, ax, positions)
    # recompute the latents for the cache (cheap projections)
    kv = x @ params["wkv_a"]
    c_kv = rmsnorm(params["kv_norm"], kv[..., : cfg.mla.kv_lora_rank], cfg.norm_eps)
    k_rope = rope(kv[..., cfg.mla.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    ckv = jnp.concatenate([c_kv, k_rope], axis=-1)
    ckv = jnp.pad(ckv, ((0, 0), (0, cache_len - l), (0, 0)))
    return y, MLACache(ckv=ckv)


def mla_decode(
    params: PyTree,
    x: Array,  # (B, 1, d)
    cache: MLACache,
    pos: Array,
    cfg: ArchConfig,
    ax: Axes,
) -> tuple[Array, MLACache]:
    """Absorbed-matmul MLA decode: attention reads are against the 576-dim
    latent, not H × head_dim expanded keys — DeepSeek-V2's KV-cache win."""
    m = cfg.mla
    nope, rdim, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    posb = jnp.reshape(pos, (1,))
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_project(params, x, cfg, posb)
    new_entry = jnp.concatenate([c_kv_new, k_rope_new], axis=-1)  # (B, 1, 576)
    ckv = jax.lax.dynamic_update_slice(cache.ckv, new_entry, (0, pos, 0))
    c, kr = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    w_uk = params["wkv_b"][..., :nope]  # (r, h, nope)
    w_uv = params["wkv_b"][..., nope:]  # (r, h, vdim)
    # absorb W_UK into the query: q_c (B, 1, H, r)
    q_c = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    scale = (nope + rdim) ** -0.5
    s = (jnp.einsum("bqhr,bsr->bhqs", q_c.astype(jnp.float32), c.astype(jnp.float32))
         + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32), kr.astype(jnp.float32))) * scale
    kv_len = pos + 1
    mask = jnp.arange(ckv.shape[1])[None, None, None, :] < kv_len
    s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhqs,bsr->bqhr", p, c.astype(jnp.float32)).astype(x.dtype)
    ctx = jnp.einsum("bqhr,rhv->bqhv", ctx_c, w_uv)
    y = jnp.einsum("bqhv,hvd->bqd", ctx, params["wo"])
    return y, MLACache(ckv=ckv)
