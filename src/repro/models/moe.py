"""Mixture-of-Experts FFN with shared experts and top-k routing.

Dispatch is the static-shape sort/scatter formulation (capacity-bounded,
MegaBlocks/flaxformer-style) rather than a (T, E, C) one-hot einsum — the
one-hot dispatch tensor for deepseek-v2 (T=32k tokens, E=160, C≈1.5k) would
be 8e9 elements; the scatter path materializes only the (E, C, d) expert
buffers, which shard over the 'model' axis (expert parallelism). Under
GSPMD the scatter/gather lower to the all-to-all pattern EP needs.

Aux losses: Switch-style load-balance + router z-loss, returned to the
caller for accumulation.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig
from .layers import Axes, dense_init, shard

Array = jax.Array
PyTree = Any


class MoEAux(NamedTuple):
    load_balance: Array
    z_loss: Array


def moe_init(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> PyTree:
    m = cfg.moe
    d, de = cfg.d_model, m.d_expert
    ks = jax.random.split(key, 5)
    e = m.num_experts
    p = {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, de), d, dtype),
        "w_up": dense_init(ks[2], (e, d, de), d, dtype),
        "w_down": dense_init(ks[3], (e, de, d), de, dtype),
    }
    if m.num_shared:
        ks2 = jax.random.split(ks[4], 3)
        ds = m.num_shared * de
        p["shared"] = {
            "w_gate": dense_init(ks2[0], (d, ds), d, dtype),
            "w_up": dense_init(ks2[1], (d, ds), d, dtype),
            "w_down": dense_init(ks2[2], (ds, d), ds, dtype),
        }
    return p


def moe_specs(ax: Axes, cfg: ArchConfig) -> PyTree:
    m = cfg.moe
    ea = ax.dim_axis(m.num_experts)  # expert parallelism over 'model'
    p = {
        "router": P(None, None),
        "w_gate": P(ea, None, None if ea else ax.dim_axis(m.d_expert)),
        "w_up": P(ea, None, None if ea else ax.dim_axis(m.d_expert)),
        "w_down": P(ea, None if ea else ax.dim_axis(m.d_expert), None),
    }
    if m.num_shared:
        ds = m.num_shared * m.d_expert
        p["shared"] = {
            "w_gate": P(None, ax.dim_axis(ds)),
            "w_up": P(None, ax.dim_axis(ds)),
            "w_down": P(ax.dim_axis(ds), None),
        }
    return p


def _dispatch_indices(expert_ids: Array, num_experts: int, capacity: int):
    """Static-shape positions: for each routed (token-slot), its slot within
    its expert's capacity buffer; overflow slots are dropped (keep=False)."""
    tk = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)  # (T*k,)
    sorted_eids = expert_ids[order]
    counts = jnp.bincount(expert_ids, length=num_experts)
    starts = jnp.cumsum(counts) - counts  # exclusive prefix
    pos_in_expert_sorted = jnp.arange(tk) - starts[sorted_eids]
    # undo the sort
    pos_in_expert = jnp.zeros((tk,), jnp.int32).at[order].set(pos_in_expert_sorted.astype(jnp.int32))
    keep = pos_in_expert < capacity
    buf_idx = expert_ids * capacity + jnp.minimum(pos_in_expert, capacity - 1)
    return buf_idx, keep


def moe_ffn(
    params: PyTree, x: Array, cfg: ArchConfig, ax: Axes, capacity_factor: float | None = None
) -> tuple[Array, MoEAux]:
    """x: (B, L, d) -> (B, L, d), plus router aux losses."""
    m: MoEConfig = cfg.moe
    b, l, d = x.shape
    t = b * l
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32)) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)  # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    cf = capacity_factor or m.capacity_factor
    capacity = max(int(t * m.top_k * cf / m.num_experts), 8)
    expert_ids = idx.reshape(-1)  # (T*k,)
    buf_idx, keep = _dispatch_indices(expert_ids, m.num_experts, capacity)

    token_of = jnp.repeat(jnp.arange(t), m.top_k)
    contrib = jnp.where(keep[:, None], xt[token_of], 0.0)
    buffers = jnp.zeros((m.num_experts * capacity, d), x.dtype).at[buf_idx].add(contrib)
    buffers = buffers.reshape(m.num_experts, capacity, d)
    buffers = shard(buffers, P(ax.dim_axis(m.num_experts), None, None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buffers, params["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buffers, params["w_up"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(
        m.num_experts * capacity, d
    )
    routed = out_buf[buf_idx] * (gates.reshape(-1)[:, None] * keep[:, None]).astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[token_of].add(routed)

    if m.num_shared:
        s = params["shared"]
        hs = jax.nn.silu(xt @ s["w_gate"]) * (xt @ s["w_up"])
        y = y + hs @ s["w_down"]

    # Switch load-balance loss: E * Σ_e f_e · p_e  (f = fraction routed,
    # p = mean router prob); z-loss: mean logsumexp^2.
    f = jnp.bincount(expert_ids, length=m.num_experts).astype(jnp.float32) / (t * m.top_k)
    pmean = jnp.mean(probs, axis=0)
    lb = m.num_experts * jnp.sum(f * pmean)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return y.reshape(b, l, d), MoEAux(lb, z)
