from .layers import Axes  # noqa: F401
from .transformer import Model, build_segments, seq_sharded_mode  # noqa: F401
