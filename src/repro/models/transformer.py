"""Config-driven decoder stack: uniform, MoE, hybrid (Jamba) and attention-
free (RWKV) architectures under one scan-over-layers implementation.

Layers are grouped into *segments* of identical structure; each segment's
params are stacked (leading repeat dim) and executed with ``lax.scan`` +
optional ``jax.checkpoint`` — the HLO holds ONE copy of each distinct layer
structure regardless of depth (llama3-405b's 126 layers compile as one
scanned body), which is what keeps the 80-cell dry-run tractable and remat
behaviour explicit.

Segments:
  * uniform archs              -> [(L, (layer,))]
  * deepseek (first-dense)     -> [(1, (dense_layer,)), (L-1, (moe_layer,))]
  * jamba (period-8 pattern)   -> [(L/8, (8 distinct sublayers,))]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from . import attention as attn
from . import mamba as mam
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from .layers import (
    Axes,
    cross_entropy,
    embed_tokens,
    embedding_init,
    embedding_specs,
    lm_logits,
    mlp,
    mlp_init,
    mlp_specs,
    rmsnorm,
    rmsnorm_init,
    rmsnorm_specs,
    shard,
)

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    mixer: str  # "a" (attention) | "m" (mamba) | "r" (rwkv)
    ffn: str  # "dense" | "moe" | "rwkv"


@dataclasses.dataclass(frozen=True)
class Segment:
    repeat: int
    layers: tuple[LayerDesc, ...]


def seq_sharded_mode(cfg: ArchConfig, ax: Axes) -> bool:
    """Sequence-parallel residual stream: used when attention heads do NOT
    divide the model axis (qwen2 14H, llama3.2 24H over 16) — the head-
    sharding fallback would otherwise replicate attention across the axis.
    Tokens shard over 'model'; MLP weights replicate; only K/V all-gather.
    (§Perf iterations 2-3.)"""
    return (
        cfg.attention == "gqa"
        and cfg.num_heads > 0
        and ax.model_size > 1
        and cfg.num_heads % ax.model_size != 0
        and cfg.d_model % ax.model_size == 0
    )


def build_segments(cfg: ArchConfig) -> tuple[Segment, ...]:
    pattern = cfg.pattern()
    moe_mask = cfg.moe_layer_mask()
    descs = []
    for i, kind in enumerate(pattern):
        if kind == "r":
            ffn = "rwkv"
        elif moe_mask[i]:
            ffn = "moe"
        else:
            ffn = "dense"
        descs.append(LayerDesc(kind, ffn))
    # greedy grouping: find smallest period that tiles the remaining layers
    n = len(descs)
    segments: list[Segment] = []
    i = 0
    # special-case leading non-repeating prefix (deepseek first-dense)
    while i < n:
        # pick the period with the most repeats (tie -> smallest period):
        # uniform stacks collapse to one scanned body; a non-repeating
        # prefix (deepseek's first dense layer) becomes its own segment.
        best = (0, None)
        for period in (1, 2, 4, 8, 16):
            if period > n - i:
                break
            blk = tuple(descs[i : i + period])
            reps = 0
            j = i
            while j + period <= n and tuple(descs[j : j + period]) == blk:
                reps += 1
                j += period
            if reps > best[0]:
                best = (reps, blk)
        reps, blk = best
        segments.append(Segment(reps, blk))
        i += reps * len(blk)
    return tuple(segments)


# -----------------------------------------------------------------------------
# single layer
# -----------------------------------------------------------------------------
def _mixer_init(key, cfg: ArchConfig, desc: LayerDesc, dtype):
    if desc.mixer == "a":
        return attn.mla_init(key, cfg, dtype) if cfg.attention == "mla" else attn.gqa_init(key, cfg, dtype)
    if desc.mixer == "m":
        return mam.mamba_init(key, cfg, dtype)
    return rwkv_mod.rwkv_time_mix_init(key, cfg, dtype)


def _mixer_specs(ax, cfg: ArchConfig, desc: LayerDesc):
    if desc.mixer == "a":
        return attn.mla_specs(ax, cfg) if cfg.attention == "mla" else attn.gqa_specs(ax, cfg)
    if desc.mixer == "m":
        return mam.mamba_specs(ax, cfg)
    return rwkv_mod.rwkv_time_mix_specs(ax, cfg)


def _ffn_init(key, cfg: ArchConfig, desc: LayerDesc, dtype):
    if desc.ffn == "moe":
        return moe_mod.moe_init(key, cfg, dtype)
    if desc.ffn == "rwkv":
        return rwkv_mod.rwkv_channel_mix_init(key, cfg, dtype)
    return mlp_init(key, cfg.d_model, cfg.d_ff, dtype)


def _ffn_specs(ax, cfg: ArchConfig, desc: LayerDesc):
    if desc.ffn == "moe":
        return moe_mod.moe_specs(ax, cfg)
    if desc.ffn == "rwkv":
        return rwkv_mod.rwkv_channel_mix_specs(ax, cfg)
    return mlp_specs(ax, cfg.d_model, cfg.d_ff, seq_sharded=seq_sharded_mode(cfg, ax))


def layer_init(key, cfg: ArchConfig, desc: LayerDesc, dtype=jnp.bfloat16) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": rmsnorm_init(cfg.d_model),
        "mixer": _mixer_init(k1, cfg, desc, dtype),
        "norm2": rmsnorm_init(cfg.d_model),
        "ffn": _ffn_init(k2, cfg, desc, dtype),
    }


def layer_specs(ax, cfg: ArchConfig, desc: LayerDesc) -> PyTree:
    return {
        "norm1": rmsnorm_specs(),
        "mixer": _mixer_specs(ax, cfg, desc),
        "norm2": rmsnorm_specs(),
        "ffn": _ffn_specs(ax, cfg, desc),
    }


def layer_forward(
    params: PyTree,
    x: Array,
    cfg: ArchConfig,
    ax: Axes,
    desc: LayerDesc,
    use_flash: bool = False,
    shard_residual: bool = False,
) -> tuple[Array, Array]:
    """Full-sequence layer. Returns (x, moe_aux_sum)."""
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if desc.mixer == "a":
        if cfg.attention == "mla":
            mix = attn.mla_forward(params["mixer"], h, cfg, ax)
        else:
            mix = attn.gqa_forward(params["mixer"], h, cfg, ax, use_flash=use_flash)
    elif desc.mixer == "m":
        mix = mam.mamba_forward(params["mixer"], h, cfg, ax)
    else:
        mix = rwkv_mod.rwkv_time_mix(params["mixer"], h, cfg, ax)
    seqsh = desc.mixer == "a" and seq_sharded_mode(cfg, ax) and x.shape[1] % ax.model_size == 0
    if seqsh:
        lspec = P(ax.b, ax.model, None)
    elif shard_residual:
        lspec = P(ax.b, None, ax.model)
    else:
        lspec = P(ax.b, None, None)
    x = x + mix
    x = shard(x, lspec)
    h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if desc.ffn == "moe":
        out, moe_aux = moe_mod.moe_ffn(params["ffn"], h2, cfg, ax)
        m = cfg.moe
        aux = m.router_aux_weight * moe_aux.load_balance + m.router_z_weight * moe_aux.z_loss
    elif desc.ffn == "rwkv":
        out = rwkv_mod.rwkv_channel_mix(params["ffn"], h2)
    else:
        out = mlp(params["ffn"], h2, ax, seq_sharded=seqsh)
    x = x + out
    return shard(x, lspec), aux


# -----------------------------------------------------------------------------
# caches (decode)
# -----------------------------------------------------------------------------
def layer_cache_init(cfg: ArchConfig, desc: LayerDesc, batch: int, seq_len: int, dtype=jnp.bfloat16):
    if desc.mixer == "a":
        if cfg.attention == "mla":
            return attn.mla_cache_init(cfg, batch, seq_len, dtype)
        return attn.gqa_cache_init(cfg, batch, seq_len, dtype)
    if desc.mixer == "m":
        return mam.mamba_state_init(cfg, batch, dtype)
    return rwkv_mod.rwkv_state_init(cfg, batch, dtype)


def layer_cache_specs(cfg: ArchConfig, desc: LayerDesc, ax: Axes):
    if desc.mixer == "a":
        if cfg.attention == "mla":
            return attn.mla_cache_specs(cfg, ax)
        return attn.gqa_cache_specs(cfg, ax)
    if desc.mixer == "m":
        return mam.mamba_state_specs(cfg, ax)
    return rwkv_mod.rwkv_state_specs(cfg, ax)


def layer_decode(
    params: PyTree,
    x: Array,
    cache,
    pos: Array,
    cfg: ArchConfig,
    ax: Axes,
    desc: LayerDesc,
):
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if desc.mixer == "a":
        if cfg.attention == "mla":
            mix, cache = attn.mla_decode(params["mixer"], h, cache, pos, cfg, ax)
        else:
            mix, cache = attn.gqa_decode(params["mixer"], h, cache, pos, cfg, ax)
    elif desc.mixer == "m":
        mix, cache = mam.mamba_decode(params["mixer"], h, cache, cfg, ax)
    else:
        mix, cache = rwkv_mod.rwkv_decode(params["mixer"], params["ffn"], h, cache, cfg)
    x = x + mix
    h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if desc.ffn == "moe":
        out, _ = moe_mod.moe_ffn(params["ffn"], h2, cfg, ax, capacity_factor=2.0)
    elif desc.ffn == "rwkv":
        out = rwkv_mod.rwkv_channel_mix(params["ffn"], h2, x_prev=cache.x_prev_cm)
        cache = cache._replace(x_prev_cm=h2[:, 0])
    else:
        out = mlp(params["ffn"], h2, ax)
    return x + out, cache


def layer_prefill(
    params: PyTree,
    x: Array,
    cfg: ArchConfig,
    ax: Axes,
    desc: LayerDesc,
    cache_len: int | None = None,
):
    """Full-seq forward that also emits the decode cache for this layer."""
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if desc.mixer == "a":
        if cfg.attention == "mla":
            mix, cache = attn.mla_prefill(params["mixer"], h, cfg, ax, cache_len)
        else:
            mix, cache = attn.gqa_prefill(params["mixer"], h, cfg, ax, cache_len)
    elif desc.mixer == "m":
        b = x.shape[0]
        st0 = mam.mamba_state_init(cfg, b, x.dtype)
        mix = mam.mamba_forward(params["mixer"], h, cfg, ax)
        # final SSM/conv state: recompute cheaply from the tail of the seq
        d_conv = (cfg.ssm.d_conv if cfg.ssm else 4)
        xz = h @ params["mixer"]["in_proj"]
        d_in = xz.shape[-1] // 2
        xs = xz[..., :d_in]
        conv_tail = xs[:, -(d_conv - 1) :, :]
        st = mam.MambaState(conv=conv_tail.astype(st0.conv.dtype), ssm=_mamba_final_state(params["mixer"], h, cfg))
        cache = st
    else:
        b = x.shape[0]
        mix = rwkv_mod.rwkv_time_mix(params["mixer"], h, cfg, ax)
        cache = rwkv_mod.RWKVState(
            x_prev_tm=h[:, -1],
            x_prev_cm=jnp.zeros_like(h[:, -1]),
            s=_rwkv_final_state(params["mixer"], h, cfg),
        )
    x = x + mix
    h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if desc.ffn == "moe":
        out, _ = moe_mod.moe_ffn(params["ffn"], h2, cfg, ax, capacity_factor=2.0)
    elif desc.ffn == "rwkv":
        out = rwkv_mod.rwkv_channel_mix(params["ffn"], h2)
        cache = cache._replace(x_prev_cm=h2[:, -1])
    else:
        out = mlp(params["ffn"], h2, ax)
    return x + out, cache


def _mamba_final_state(mixer: PyTree, h: Array, cfg: ArchConfig) -> Array:
    """Final SSM state after the full sequence (re-runs the scan carry)."""
    x, z, d_in, d_state, dt_rank = mam._project(mixer, h, cfg)
    x = jax.nn.silu(mam._conv_causal(x, mixer["conv_w"], mixer["conv_b"]))
    dt, b, c, a = mam._ssm_params(mixer, x, d_state, dt_rank)
    h0 = jnp.zeros((h.shape[0], d_in, d_state), jnp.float32)
    hf, _ = mam._ssm_scan(x.astype(jnp.float32), dt, b, c, a, h0)
    return hf


def _rwkv_final_state(mixer: PyTree, h: Array, cfg: ArchConfig) -> Array:
    b, l, d = h.shape
    nh, hs, _ = rwkv_mod._dims(cfg)
    x_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    k = rwkv_mod._mix(h, x_prev, mixer["mix_k"]) @ mixer["wk"]
    v = rwkv_mod._mix(h, x_prev, mixer["mix_v"]) @ mixer["wv"]
    w = rwkv_mod._decay(mixer, rwkv_mod._mix(h, x_prev, mixer["mix_w"]))
    kh = k.reshape(b, l, nh, hs).astype(jnp.float32)
    vh = v.reshape(b, l, nh, hs).astype(jnp.float32)
    wh = w.reshape(b, l, nh, hs)
    rh = jnp.zeros_like(kh)  # receptance unused for the state
    s0 = jnp.zeros((b, nh, hs, hs), jnp.float32)
    if l % rwkv_mod._WKV_CHUNK == 0:
        sf, _ = rwkv_mod._wkv_chunked(rh, kh, vh, wh, mixer["u"], s0)
    else:
        sf, _ = rwkv_mod._wkv_naive(rh, kh, vh, wh, mixer["u"], s0)
    return sf


# -----------------------------------------------------------------------------
# the model
# -----------------------------------------------------------------------------
class Model:
    """Functional model bound to (cfg, axes). Params/caches are pytrees."""

    def __init__(self, cfg: ArchConfig, ax: Axes | None = None, remat: str = "full",
                 use_flash: bool = False, dtype=jnp.bfloat16, shard_residual: bool = False,
                 remat_group: int = 1):
        self.cfg = cfg
        self.ax = ax or Axes(batch=("data",), model="model", model_size=1)
        self.segments = build_segments(cfg)
        assert sum(s.repeat * len(s.layers) for s in self.segments) == cfg.num_layers
        self.remat = remat
        self.use_flash = use_flash
        self.dtype = dtype
        # §Perf (llama3-405b, iteration A — REFUTED, kept for ablation):
        # sharding the residual stream over 'model' cut the remat stash 16x
        # but added a larger volume of per-matmul activation all-gathers.
        self.shard_residual = (
            shard_residual
            and cfg.d_model % self.ax.model_size == 0
            and not seq_sharded_mode(cfg, self.ax)
        )
        # §Perf iteration C: checkpoint every `remat_group` layers instead
        # of every layer — stash shrinks by g at ~(g-1)/g extra fwd
        # recompute of grouped layers' peers (weight gathers unchanged).
        self.remat_group = max(1, remat_group)

    # ---- init / specs ---------------------------------------------------------
    def init(self, key: Array) -> PyTree:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.segments) + 1)
        p: dict[str, Any] = {
            "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, cfg.tie_embeddings, self.dtype),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        for si, seg in enumerate(self.segments):
            def one(k):
                lks = jax.random.split(k, len(seg.layers))
                return {f"l{i}": layer_init(lks[i], cfg, d, self.dtype) for i, d in enumerate(seg.layers)}

            seg_keys = jax.random.split(keys[si + 1], seg.repeat)
            p[f"seg{si}"] = jax.vmap(one)(seg_keys)
        return p

    def param_specs(self) -> PyTree:
        cfg, ax = self.cfg, self.ax
        p: dict[str, Any] = {
            "embed": embedding_specs(ax, cfg.vocab_size, cfg.tie_embeddings),
            "final_norm": rmsnorm_specs(),
        }
        for si, seg in enumerate(self.segments):
            seg_spec = {
                f"l{i}": layer_specs(ax, cfg, d) for i, d in enumerate(seg.layers)
            }
            # stacked leading repeat dim -> prepend None to every spec
            p[f"seg{si}"] = jax.tree.map(
                lambda s: P(*((None,) + tuple(s))), seg_spec,
                is_leaf=lambda s: isinstance(s, P),
            )
        return p

    # ---- forward --------------------------------------------------------------
    def _segment_body(self, seg: Segment, group: int = 1):
        cfg, ax = self.cfg, self.ax

        def apply_layers(carry, seg_params):
            x, aux = carry
            for i, d in enumerate(seg.layers):
                x, a = layer_forward(seg_params[f"l{i}"], x, cfg, ax, d, self.use_flash,
                                     self.shard_residual)
                aux = aux + a
            return (x, aux), ()

        if group == 1:
            body = apply_layers
        else:
            # nested remat: the group checkpoint bounds the stash to one
            # entry per g layers; the inner per-layer checkpoints bound the
            # group-backward working set to ONE layer's intermediates
            inner = jax.checkpoint(apply_layers, prevent_cse=False)

            def body(carry, grouped):
                for j in range(group):
                    carry, _ = inner(carry, jax.tree.map(lambda a: a[j], grouped))
                return carry, ()

        if self.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif self.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                prevent_cse=False,
            )
        return body

    def backbone(self, params: PyTree, x: Array) -> tuple[Array, Array]:
        """(B, L, d) -> (hidden, moe_aux)."""
        aux = jnp.zeros((), jnp.float32)
        for si, seg in enumerate(self.segments):
            # largest divisor of the stack depth <= requested group size
            g = max(d for d in range(1, self.remat_group + 1) if seg.repeat % d == 0)
            seg_params = params[f"seg{si}"]
            if g > 1:
                seg_params = jax.tree.map(
                    lambda a: a.reshape(a.shape[0] // g, g, *a.shape[1:]), seg_params
                )
            body = self._segment_body(seg, group=g)
            (x, aux), _ = jax.lax.scan(body, (x, aux), seg_params)
        return rmsnorm(params["final_norm"], x, self.cfg.norm_eps), aux

    def embed_input(self, params: PyTree, batch: dict[str, Array]) -> Array:
        if self.cfg.input_mode == "embeddings" and "embeds" in batch:
            x = batch["embeds"].astype(self.dtype)
        else:
            x = embed_tokens(params["embed"], batch["tokens"])
        if seq_sharded_mode(self.cfg, self.ax) and x.shape[1] % self.ax.model_size == 0:
            return shard(x, P(self.ax.b, self.ax.model, None))
        if self.shard_residual:
            return shard(x, P(self.ax.b, None, self.ax.model))
        return shard(x, P(self.ax.b, None, None))

    def loss_fn(self, params: PyTree, batch: dict[str, Array]) -> Array:
        x = self.embed_input(params, batch)
        h, aux = self.backbone(params, x)
        logits = lm_logits(params["embed"], h, self.ax)
        return cross_entropy(logits, batch["labels"]) + aux

    # ---- prefill / decode -----------------------------------------------------
    def cache_init(self, batch: int, seq_len: int) -> PyTree:
        caches = {}
        for si, seg in enumerate(self.segments):
            def one(_):
                return {
                    f"l{i}": layer_cache_init(self.cfg, d, batch, seq_len, self.dtype)
                    for i, d in enumerate(seg.layers)
                }

            caches[f"seg{si}"] = jax.vmap(one)(jnp.arange(seg.repeat))
        return caches

    def cache_specs(self) -> PyTree:
        out = {}
        for si, seg in enumerate(self.segments):
            seg_spec = {
                f"l{i}": layer_cache_specs(self.cfg, d, self.ax) for i, d in enumerate(seg.layers)
            }
            out[f"seg{si}"] = jax.tree.map(
                lambda s: P(*((None,) + tuple(s))), seg_spec,
                is_leaf=lambda s: isinstance(s, P),
            )
        return out

    def prefill(
        self, params: PyTree, batch: dict[str, Array], cache_len: int | None = None
    ) -> tuple[Array, PyTree]:
        """Returns (last-token logits, caches with `cache_len` decode capacity)."""
        x = self.embed_input(params, batch)
        caches = {}
        for si, seg in enumerate(self.segments):
            def body(x, seg_params):
                new_caches = {}
                for i, d in enumerate(seg.layers):
                    x, c = layer_prefill(seg_params[f"l{i}"], x, self.cfg, self.ax, d, cache_len)
                    new_caches[f"l{i}"] = c
                return x, new_caches

            x, caches[f"seg{si}"] = jax.lax.scan(body, x, params[f"seg{si}"])
        h = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        logits = lm_logits(params["embed"], h[:, -1:], self.ax)
        return logits, caches

    def decode_step(
        self, params: PyTree, caches: PyTree, tokens: Array, pos: Array
    ) -> tuple[Array, PyTree]:
        """tokens: (B, 1) int32; pos: scalar int32. Returns (logits, caches)."""
        x = embed_tokens(params["embed"], tokens)
        x = shard(x, P(self.ax.b, None, None))
        new_caches = {}
        for si, seg in enumerate(self.segments):
            def body(x, scan_in):
                seg_params, cache = scan_in
                new_cache = {}
                for i, d in enumerate(seg.layers):
                    x, c = layer_decode(
                        seg_params[f"l{i}"], x, cache[f"l{i}"], pos, self.cfg, self.ax, d
                    )
                    new_cache[f"l{i}"] = c
                return x, new_cache

            x, new_caches[f"seg{si}"] = jax.lax.scan(
                body, x, (params[f"seg{si}"], caches[f"seg{si}"])
            )
        h = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        logits = lm_logits(params["embed"], h, self.ax)
        return logits, new_caches
