"""Synthetic data generators matching the paper's experimental setup (§IV-A).

  * ``nmf_data`` — "synthetic data generator with random Gaussian features
    for a predetermined k": V = W_true H_true + noise, 1000x1100 at full
    scale, with block-structured factors so silhouette-vs-k is square-wave.
  * ``blob_data`` — K-Means experiment: Gaussian clusters (std 0.5) with
    overlaid random noise.
  * ``rescal_data`` — relational tensors X_r = A R_r A^T for RESCALk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def nmf_data(
    key: Array,
    n: int = 1000,
    m: int = 1100,
    k_true: int = 8,
    noise: float = 0.01,
    dtype=jnp.float32,
) -> tuple[Array, Array, Array]:
    """Nonnegative V (n, m) with a planted rank-k_true block structure.

    Each latent component owns a contiguous block of rows/columns with
    strong loading plus a weak random background — clean, well-separated
    components so NMFk's silhouette exhibits the square-wave-vs-k shape the
    paper's pruning heuristic assumes.
    """
    kw, kh, kn = jax.random.split(key, 3)
    rows_per = n // k_true
    cols_per = m // k_true
    # dominant block loadings ~ |N(1, 0.1)|, background ~ U[0, 0.02]
    w_bg = jax.random.uniform(kw, (n, k_true), dtype, 0.0, 0.02)
    h_bg = jax.random.uniform(kh, (k_true, m), dtype, 0.0, 0.02)
    row_block = jnp.clip(jnp.arange(n) // max(rows_per, 1), 0, k_true - 1)
    col_block = jnp.clip(jnp.arange(m) // max(cols_per, 1), 0, k_true - 1)
    w_sig = jax.nn.one_hot(row_block, k_true, dtype=dtype)
    h_sig = jax.nn.one_hot(col_block, k_true, dtype=dtype).T
    kw2, kh2 = jax.random.split(kn)
    w = w_bg + w_sig * jnp.abs(1.0 + 0.1 * jax.random.normal(kw2, (n, k_true), dtype))
    h = h_bg + h_sig * jnp.abs(1.0 + 0.1 * jax.random.normal(kh2, (k_true, m), dtype))
    v = w @ h
    v = v + noise * jax.random.uniform(kn, (n, m), dtype)
    return v, w, h


def blob_data(
    key: Array,
    n: int = 600,
    d: int = 8,
    k_true: int = 5,
    std: float = 0.5,
    noise: float = 0.05,
    spread: float = 4.0,
    dtype=jnp.float32,
) -> tuple[Array, Array]:
    """Gaussian blobs (paper §IV-A K-Means: std=.5 + overlaid noise)."""
    kc, kx, kn, ka = jax.random.split(key, 4)
    centers = spread * jax.random.normal(kc, (k_true, d), dtype)
    labels = jax.random.randint(ka, (n,), 0, k_true)
    x = centers[labels] + std * jax.random.normal(kx, (n, d), dtype)
    x = x + noise * jax.random.normal(kn, (n, d), dtype)
    return x, labels


def rescal_data(
    key: Array,
    n_entities: int = 120,
    n_relations: int = 4,
    k_true: int = 6,
    noise: float = 0.01,
    dtype=jnp.float32,
) -> tuple[Array, Array, Array]:
    """Nonnegative relational tensor X (r, n, n) = A R_r A^T + noise."""
    ka, kr, kn = jax.random.split(key, 3)
    blocks = jnp.clip(jnp.arange(n_entities) // max(n_entities // k_true, 1), 0, k_true - 1)
    a = jax.nn.one_hot(blocks, k_true, dtype=dtype)
    a = a + jax.random.uniform(ka, a.shape, dtype, 0.0, 0.05)
    r = jax.random.uniform(kr, (n_relations, k_true, k_true), dtype, 0.0, 1.0)
    # sparsify relations toward block-diagonal interactions for separability
    r = r * (0.2 + 0.8 * jnp.eye(k_true, dtype=dtype)[None])
    x = jnp.einsum("ik,rkl,jl->rij", a, r, a)
    x = x + noise * jax.random.uniform(kn, x.shape, dtype)
    return x, a, r
