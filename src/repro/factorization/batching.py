"""Shared lane layout for the mask-padded ``*_batched`` entry points.

All three batched fits (``kmeans_batched``, ``nmf_batched``,
``nmfk_score_batched``) promise the same contract: lane i uses
``fold_in(key, ks[i])`` — matching the per-k evaluators' key schedule —
and every lane runs at a common padded rank ``k_pad >= max(ks)``. Keeping
the validation and key derivation here stops the schedule (which the
batched-vs-per-k equivalence tests depend on) from drifting between entry
points.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def batched_lanes(
    ks: Sequence[int], key: jax.Array, k_pad: int | None
) -> tuple[jax.Array, jax.Array, int]:
    """Validate ``ks``/``k_pad`` and derive per-lane keys.

    Returns (ks_arr (b,), keys (b, 2), k_pad) with keys[i] = fold_in(key, ks[i]).
    """
    ks = [int(k) for k in ks]
    if not ks:
        raise ValueError("ks must be non-empty")
    k_pad = max(ks) if k_pad is None else k_pad
    if k_pad < max(ks):
        raise ValueError(f"k_pad={k_pad} smaller than max(ks)={max(ks)}")
    keys = jnp.stack([jax.random.fold_in(key, k) for k in ks])
    return jnp.asarray(ks), keys, k_pad
