"""Shared lane layout for the mask-padded ``*_batched`` entry points.

All three batched fits (``kmeans_batched``, ``nmf_batched``,
``nmfk_score_batched``) promise the same contract: lane i uses
``fold_in(key, ks[i])`` — matching the per-k evaluators' key schedule —
and every lane runs at a common padded rank ``k_pad >= max(ks)``. Keeping
the validation and key derivation here stops the schedule (which the
batched-vs-per-k equivalence tests depend on) from drifting between entry
points.

This module also owns the **shape-bucketing policy** the evaluation planes
use to pick a padded batch size (``bucket_batch``): pow2 rounding with a
floor (the mesh lane count for sharded planes) keeps the set of distinct
compiled ``(batch, k_pad)`` shapes small and stable across searches, and
reuse of an already-compiled bucket makes scalar fallbacks free.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import jax
import jax.numpy as jnp


class WarmStartCache:
    """Completed-fit W factors keyed by (k, perturbation) for cross-k warm starts.

    Binary Bleed's pre-order visit order clusters nearby k's in time, so a
    freshly drained lane usually has a recently-completed neighbor whose
    aligned W is a far better starting point than a random draw. ``nearest``
    prefers the same perturbation index (its noise realization matches the
    new lane's), breaking distance ties toward smaller k (truncating a
    larger fit discards information; padding a smaller one keeps it all).

    Stores at most ``per_k`` entries per k (one per perturbation is plenty)
    and evicts whole k's FIFO beyond ``max_ks`` — W factors are (n, k_pad)
    and the search only ever benefits from recent neighbors.
    """

    def __init__(self, window: int = 8, max_ks: int = 16):
        self.window = int(window)
        self.max_ks = int(max_ks)
        self._by_k: dict[int, dict[int, jax.Array]] = {}
        self.hits = 0
        self.misses = 0

    def put(self, k: int, perturbation: int, w: jax.Array) -> None:
        slot = self._by_k.setdefault(int(k), {})
        slot[int(perturbation)] = w
        while len(self._by_k) > self.max_ks:
            self._by_k.pop(next(iter(self._by_k)))

    def nearest(self, k: int, perturbation: int) -> tuple[int, jax.Array] | None:
        """Best (k_src, w_src) within ``window`` of k, or None (cold start)."""
        k, perturbation = int(k), int(perturbation)
        best = None
        for k_src, slot in self._by_k.items():
            dist = abs(k_src - k)
            if dist > self.window or not slot:
                continue
            p_src = perturbation if perturbation in slot else next(iter(slot))
            # rank: distance, then mismatched perturbation, then prefer k_src < k
            rank = (dist, 0 if p_src == perturbation else 1, 0 if k_src <= k else 1)
            if best is None or rank < best[0]:
                best = (rank, k_src, slot[p_src])
        if best is None:
            self.misses += 1
            return None
        self.hits += 1
        return best[1], best[2]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    p = 1
    while p < n:
        p *= 2
    return p


def round_up_multiple(n: int, step: int) -> int:
    return ((n + step - 1) // step) * step


def bucket_batch(
    n_real: int,
    *,
    lanes: int = 1,
    bucket_min: int = 1,
    cap: int | None = None,
    compiled: Iterable[int] = (),
) -> int:
    """Pick the padded batch size for a dispatch of ``n_real`` lanes.

    Policy (in priority order):
      1. fresh target = pow2(max(n_real, bucket_min)) rounded up to a
         multiple of ``lanes`` (sharded planes split the batch evenly over
         the mesh's lane axis);
      2. ``cap`` bounds the padding (never below n_real itself, rounded to
         a lane multiple — correctness beats the cap when they conflict);
      3. if the fresh target is not yet compiled but some already-compiled
         bucket can hold this dispatch (>= n_real, within the cap), reuse
         the smallest such bucket instead of minting a new shape — this is
         what keeps scalar fallbacks and odd-sized waves from each paying
         their own jit compilation.
    """
    if n_real < 1:
        raise ValueError("n_real must be >= 1")
    target = next_pow2(max(n_real, bucket_min))
    if lanes > 1:
        target = round_up_multiple(target, lanes)
    floor = round_up_multiple(n_real, lanes) if lanes > 1 else n_real
    cap_r = None
    if cap is not None:
        cap_r = round_up_multiple(cap, lanes) if lanes > 1 else cap
        target = max(floor, min(target, cap_r))
    compiled = set(compiled)
    if target in compiled:
        return target
    fits = sorted(
        b for b in compiled if b >= floor and (cap_r is None or b <= max(cap_r, floor))
    )
    if fits:
        return fits[0]
    return target


def batched_lanes(
    ks: Sequence[int], key: jax.Array, k_pad: int | None
) -> tuple[jax.Array, jax.Array, int]:
    """Validate ``ks``/``k_pad`` and derive per-lane keys.

    Returns (ks_arr (b,), keys (b, 2), k_pad) with keys[i] = fold_in(key, ks[i]).
    """
    ks = [int(k) for k in ks]
    if not ks:
        raise ValueError("ks must be non-empty")
    k_pad = max(ks) if k_pad is None else k_pad
    if k_pad < max(ks):
        raise ValueError(f"k_pad={k_pad} smaller than max(ks)={max(ks)}")
    keys = jnp.stack([jax.random.fold_in(key, k) for k in ks])
    return jnp.asarray(ks), keys, k_pad
