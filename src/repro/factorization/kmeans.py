"""K-Means in JAX: k-means++ seeding + Lloyd iterations (lax.while_loop).

Used (a) as the paper's K-Means experiment substrate (Davies-Bouldin,
minimization task), and (b) inside NMFk's custom W-column clustering.

``kmeans_batched`` is the wavefront-executor entry point: centroids are
padded to a common ``k_pad``, inactive slots are masked out of assignment /
update / convergence, and the whole fit is vmapped over the k axis — one
jit compilation at ``k_pad`` serves every k in a wave. The masked fit is
draw-for-draw identical to the per-k fit (the padded slots consume the same
key-split schedule but their draws are discarded), so lane i reproduces
``kmeans(x, ks[i], fold_in(key, ks[i]))``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.scoring import pairwise_sq_dists

from .batching import batched_lanes

Array = jax.Array


class KMeansResult(NamedTuple):
    centroids: Array  # (k, d)
    labels: Array  # (n,)
    inertia: Array  # sum of squared distances to assigned centroid
    iters: Array


def _kmeanspp_init(key: Array, x: Array, k: int) -> Array:
    """k-means++ seeding: sample next center ∝ squared distance."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, carry):
        centers, key = carry
        d2 = pairwise_sq_dists(x, centers)  # (n, k)
        # distance to nearest chosen center; unchosen slots masked by i
        mask = jnp.arange(k) < i
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
        dmin = jnp.min(d2, axis=1)
        key, sub = jax.random.split(key)
        p = dmin / jnp.maximum(jnp.sum(dmin), 1e-12)
        idx = jax.random.choice(sub, n, p=p)
        return centers.at[i].set(x[idx]), key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers0, key))
    return centers


@functools.partial(jax.jit, static_argnames=("k", "max_iters"))
def kmeans(
    x: Array,
    k: int,
    key: Array,
    max_iters: int = 100,
    tol: float = 1e-6,
) -> KMeansResult:
    """Lloyd's algorithm; empty clusters re-seeded at the farthest point."""
    centers = _kmeanspp_init(key, x, k)

    def assign(centers):
        d2 = pairwise_sq_dists(x, centers)
        labels = jnp.argmin(d2, axis=1)
        inertia = jnp.sum(jnp.min(d2, axis=1))
        return labels, inertia

    def cond(carry):
        _, _, delta, it = carry
        return jnp.logical_and(delta > tol, it < max_iters)

    def body(carry):
        centers, _, _, it = carry
        labels, _ = assign(centers)
        onehot = jax.nn.one_hot(labels, k, dtype=x.dtype)  # (n, k)
        counts = jnp.sum(onehot, axis=0)  # (k,)
        sums = onehot.T @ x  # (k, d)
        new_centers = sums / jnp.maximum(counts[:, None], 1.0)
        # re-seed empty clusters at the point farthest from its centroid
        d2 = pairwise_sq_dists(x, new_centers)
        far_idx = jnp.argmax(jnp.min(d2, axis=1))
        new_centers = jnp.where(
            (counts[:, None] == 0), x[far_idx][None, :], new_centers
        )
        delta = jnp.max(jnp.abs(new_centers - centers))
        return new_centers, labels, delta, it + 1

    labels0, _ = assign(centers)
    centers, labels, _, iters = jax.lax.while_loop(
        cond, body, (centers, labels0, jnp.asarray(jnp.inf, x.dtype), jnp.asarray(0))
    )
    labels, inertia = assign(centers)
    return KMeansResult(centers, labels, inertia, iters)


def _masked_kmeanspp_init(key: Array, x: Array, k_eff: Array, k_pad: int) -> Array:
    """k-means++ at padded width: slots >= k_eff stay zero, draws for them
    are burned (not applied) so active-slot draws match the per-k init."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centers0 = jnp.zeros((k_pad, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, carry):
        centers, key = carry
        d2 = pairwise_sq_dists(x, centers)  # (n, k_pad)
        mask = jnp.arange(k_pad) < jnp.minimum(i, k_eff)
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
        dmin = jnp.min(d2, axis=1)
        key, sub = jax.random.split(key)
        p = dmin / jnp.maximum(jnp.sum(dmin), 1e-12)
        idx = jax.random.choice(sub, n, p=p)
        centers = jnp.where(i < k_eff, centers.at[i].set(x[idx]), centers)
        return centers, key

    centers, _ = jax.lax.fori_loop(1, k_pad, body, (centers0, key))
    return centers


def _masked_assign(x: Array, centers: Array, k_eff: Array, k_pad: int):
    """Nearest-active-center labels + inertia for masked centroids."""
    active = jnp.arange(k_pad) < k_eff
    d2 = pairwise_sq_dists(x, centers)
    d2 = jnp.where(active[None, :], d2, jnp.inf)
    labels = jnp.argmin(d2, axis=1)
    inertia = jnp.sum(jnp.min(d2, axis=1))
    return labels, inertia


def _masked_lloyd(
    x: Array, centers: Array, k_eff: Array, k_pad: int, max_iters: int, tol: float
) -> tuple[Array, Array, Array]:
    """Up to ``max_iters`` masked Lloyd iterations from ``centers``.

    Returns (centers, delta, iters_done). The resumable body shared by
    ``_kmeans_masked`` and the chunked abort path: the while_loop condition
    stops *exactly* when ``delta <= tol``, so running it in host-visible
    chunks (stop when the returned delta clears tol) applies the same
    iteration sequence as one long call — chunk boundaries are bitwise
    invisible.
    """
    active = jnp.arange(k_pad) < k_eff  # (k_pad,)

    def cond(carry):
        _, delta, it = carry
        return jnp.logical_and(delta > tol, it < max_iters)

    def body(carry):
        centers, _, it = carry
        labels, _ = _masked_assign(x, centers, k_eff, k_pad)
        onehot = jax.nn.one_hot(labels, k_pad, dtype=x.dtype)  # (n, k_pad)
        counts = jnp.sum(onehot, axis=0)
        sums = onehot.T @ x
        new_centers = sums / jnp.maximum(counts[:, None], 1.0)
        # re-seed empty *active* clusters at the farthest point
        d2 = pairwise_sq_dists(x, new_centers)
        d2 = jnp.where(active[None, :], d2, jnp.inf)
        far_idx = jnp.argmax(jnp.min(d2, axis=1))
        new_centers = jnp.where(
            (counts[:, None] == 0) & active[:, None], x[far_idx][None, :], new_centers
        )
        new_centers = jnp.where(active[:, None], new_centers, 0.0)
        delta = jnp.max(jnp.abs(new_centers - centers) * active[:, None])
        return new_centers, delta, it + 1

    return jax.lax.while_loop(
        cond, body, (centers, jnp.asarray(jnp.inf, x.dtype), jnp.asarray(0))
    )


@functools.partial(jax.jit, static_argnames=("k_pad",))
def _kmeans_masked_init(x: Array, k_eff: Array, key: Array, k_pad: int) -> Array:
    """Jit'd masked k-means++ seeding (the chunked path's lane init)."""
    return _masked_kmeanspp_init(key, x, k_eff, k_pad)


@functools.partial(jax.jit, static_argnames=("k_pad", "chunk"))
def _kmeans_masked_chunk(
    x: Array, centers: Array, k_eff: Array, k_pad: int, chunk: int, tol: float = 1e-6
) -> tuple[Array, Array, Array]:
    """Resumable chunk of a masked Lloyd fit: up to ``chunk`` iterations.

    Returns (centers, delta, iters_done); the caller stops when delta <=
    tol (bitwise-equal to the unchunked fit — the inner while_loop halts on
    exactly the same condition) or polls §III-D abort between chunks.
    """
    return _masked_lloyd(x, centers, k_eff, k_pad, chunk, tol)


@functools.partial(jax.jit, static_argnames=("k_pad",))
def _kmeans_masked_assign(
    x: Array, centers: Array, k_eff: Array, k_pad: int
) -> tuple[Array, Array]:
    """Jit'd final assignment for the chunked path."""
    return _masked_assign(x, centers, k_eff, k_pad)


@functools.partial(jax.jit, static_argnames=("k_pad", "max_iters"))
def _kmeans_masked(
    x: Array,
    k_eff: Array,
    key: Array,
    k_pad: int,
    max_iters: int = 100,
    tol: float = 1e-6,
) -> KMeansResult:
    """Lloyd's algorithm on k_pad slots of which only the first k_eff live."""
    centers = _masked_kmeanspp_init(key, x, k_eff, k_pad)
    centers, _, iters = _masked_lloyd(x, centers, k_eff, k_pad, max_iters, tol)
    labels, inertia = _masked_assign(x, centers, k_eff, k_pad)
    return KMeansResult(centers, labels, inertia, iters)


def kmeans_batched(
    x: Array,
    ks: Sequence[int],
    key: Array,
    k_pad: int | None = None,
    max_iters: int = 100,
) -> KMeansResult:
    """Fit every k in ``ks`` as one padded vmapped K-Means.

    Returns a KMeansResult with a leading batch axis aligned with ``ks``:
    centroids (b, k_pad, d) — slots >= ks[i] are zero, labels (b, n) in
    [0, ks[i]). Lane i matches ``kmeans(x, ks[i], fold_in(key, ks[i]))``.
    """
    ks_arr, keys, k_pad = batched_lanes(ks, key, k_pad)
    return jax.vmap(
        lambda k_eff, sub: _kmeans_masked(x, k_eff, sub, k_pad, max_iters)
    )(ks_arr, keys)


def kmeans_multi_restart(
    x: Array, k: int, key: Array, restarts: int = 4, max_iters: int = 100
) -> KMeansResult:
    """vmapped multi-restart; returns the lowest-inertia solution."""
    keys = jax.random.split(key, restarts)
    results = jax.vmap(lambda kk: kmeans(x, k, kk, max_iters))(keys)
    best = jnp.argmin(results.inertia)
    return KMeansResult(
        results.centroids[best], results.labels[best], results.inertia[best], results.iters[best]
    )
