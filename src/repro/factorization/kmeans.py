"""K-Means in JAX: k-means++ seeding + Lloyd iterations (lax.while_loop).

Used (a) as the paper's K-Means experiment substrate (Davies-Bouldin,
minimization task), and (b) inside NMFk's custom W-column clustering.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.scoring import pairwise_sq_dists

Array = jax.Array


class KMeansResult(NamedTuple):
    centroids: Array  # (k, d)
    labels: Array  # (n,)
    inertia: Array  # sum of squared distances to assigned centroid
    iters: Array


def _kmeanspp_init(key: Array, x: Array, k: int) -> Array:
    """k-means++ seeding: sample next center ∝ squared distance."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, carry):
        centers, key = carry
        d2 = pairwise_sq_dists(x, centers)  # (n, k)
        # distance to nearest chosen center; unchosen slots masked by i
        mask = jnp.arange(k) < i
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
        dmin = jnp.min(d2, axis=1)
        key, sub = jax.random.split(key)
        p = dmin / jnp.maximum(jnp.sum(dmin), 1e-12)
        idx = jax.random.choice(sub, n, p=p)
        return centers.at[i].set(x[idx]), key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers0, key))
    return centers


@functools.partial(jax.jit, static_argnames=("k", "max_iters"))
def kmeans(
    x: Array,
    k: int,
    key: Array,
    max_iters: int = 100,
    tol: float = 1e-6,
) -> KMeansResult:
    """Lloyd's algorithm; empty clusters re-seeded at the farthest point."""
    centers = _kmeanspp_init(key, x, k)

    def assign(centers):
        d2 = pairwise_sq_dists(x, centers)
        labels = jnp.argmin(d2, axis=1)
        inertia = jnp.sum(jnp.min(d2, axis=1))
        return labels, inertia

    def cond(carry):
        _, _, delta, it = carry
        return jnp.logical_and(delta > tol, it < max_iters)

    def body(carry):
        centers, _, _, it = carry
        labels, _ = assign(centers)
        onehot = jax.nn.one_hot(labels, k, dtype=x.dtype)  # (n, k)
        counts = jnp.sum(onehot, axis=0)  # (k,)
        sums = onehot.T @ x  # (k, d)
        new_centers = sums / jnp.maximum(counts[:, None], 1.0)
        # re-seed empty clusters at the point farthest from its centroid
        d2 = pairwise_sq_dists(x, new_centers)
        far_idx = jnp.argmax(jnp.min(d2, axis=1))
        new_centers = jnp.where(
            (counts[:, None] == 0), x[far_idx][None, :], new_centers
        )
        delta = jnp.max(jnp.abs(new_centers - centers))
        return new_centers, labels, delta, it + 1

    labels0, _ = assign(centers)
    centers, labels, _, iters = jax.lax.while_loop(
        cond, body, (centers, labels0, jnp.asarray(jnp.inf, x.dtype), jnp.asarray(0))
    )
    labels, inertia = assign(centers)
    return KMeansResult(centers, labels, inertia, iters)


def kmeans_multi_restart(
    x: Array, k: int, key: Array, restarts: int = 4, max_iters: int = 100
) -> KMeansResult:
    """vmapped multi-restart; returns the lowest-inertia solution."""
    keys = jax.random.split(key, restarts)
    results = jax.vmap(lambda kk: kmeans(x, k, kk, max_iters))(keys)
    best = jnp.argmin(results.inertia)
    return KMeansResult(
        results.centroids[best], results.labels[best], results.inertia[best], results.iters[best]
    )
