"""Batched evaluation planes: mask-padded multi-k fits behind ``EvalPlane``.

These are the hardware-shaped back ends of the wavefront executor
(``repro.core.evalplane.WavefrontScheduler``): a whole frontier of k values
becomes ONE vmapped, jit'd fit at a common padded rank, so the per-k
trace/JIT/dispatch cost the thread path pays |wave| times is paid once.

Shape discipline (what keeps compile counts ~O(1) instead of O(|K|)):

  * the rank axis is padded to a fixed ``k_pad`` (default: the largest k
    the plane will ever see — pass the top of the search range);
  * the batch axis is padded to the next power of two (duplicating the
    first k; duplicate lanes are discarded), so every wave of similar size
    reuses the same compiled executable. ``WavefrontScheduler(max_wave=N)``
    sets the plane's ``dispatch_cap`` so this padding never exceeds an
    explicit memory bound; ``pad_batch=False`` disables it entirely.

``shapes_compiled`` records the distinct (batch, k_pad) shapes dispatched —
a deterministic proxy for jit compilations that the wavefront benchmark
compares against the thread path's one-compilation-per-distinct-k.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.obs import get_metrics, get_tracer

from .kmeans import kmeans_batched
from .nmfk import nmfk_score_batched

Array = jax.Array


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class _BatchPlaneBase:
    """Shared padding / accounting for the batched factorization planes."""

    def __init__(self, k_pad: int | None, pad_batch: bool):
        self.k_pad = k_pad
        self.pad_batch = pad_batch
        # dispatch cap (number of lanes per batch). WavefrontScheduler sets
        # this to its max_wave so pow2 batch padding never exceeds the
        # device-memory bound the cap was chosen for.
        self.dispatch_cap: int | None = None
        self.n_dispatches = 0
        self.n_evals = 0
        self.shapes_compiled: set[tuple[int, int]] = set()

    def _pad_ks(self, ks: Sequence[int]) -> tuple[list[int], int, int]:
        ks = [int(k) for k in ks]
        if not ks:
            raise ValueError("evaluate_batch needs at least one k")
        k_pad = self.k_pad if self.k_pad is not None else max(ks)
        if k_pad < max(ks):
            raise ValueError(f"plane k_pad={k_pad} smaller than requested k={max(ks)}")
        n_real = len(ks)
        if self.pad_batch:
            target = _next_pow2(n_real)
            if self.dispatch_cap is not None:
                target = max(n_real, min(target, self.dispatch_cap))
            ks = ks + [ks[0]] * (target - n_real)
        self.n_dispatches += 1
        self.n_evals += n_real
        shape = (len(ks), k_pad)
        if shape not in self.shapes_compiled:
            # new padded shape == a jit cache miss on the next dispatch: the
            # batched fits are compiled per (batch, k_pad), so recompiles
            # become visible in the trace instead of silent wall-clock.
            self.shapes_compiled.add(shape)
            get_metrics().inc("compile_count")
            get_tracer().event("compile", track="device:0", batch=shape[0], k_pad=shape[1])
        return ks, k_pad, n_real

    def evaluate_one(self, k: int, should_abort=None) -> float:
        del should_abort  # one fused dispatch; no chunk boundary to poll
        return self.evaluate_batch([k])[0]


class NMFkBatchPlane(_BatchPlaneBase):
    """NMFk stability scoring of a whole wave as one padded vmapped ensemble.

    Per-lane RNG is ``fold_in(key, k)`` — the same schedule as
    ``make_nmfk_evaluator`` — so the batched and threaded executors agree
    on the score landscape (exactly at k == k_pad, to init-draw noise
    below it).
    """

    def __init__(
        self,
        v: Array,
        key: Array,
        n_perturbs: int = 8,
        nmf_iters: int = 150,
        epsilon: float = 0.015,
        statistic: str = "min",
        k_pad: int | None = None,
        pad_batch: bool = True,
        use_kernel: bool = False,
    ):
        super().__init__(k_pad, pad_batch)
        if statistic not in ("min", "mean"):
            raise ValueError(f"statistic must be 'min' or 'mean', got {statistic!r}")
        self.v = v
        self.key = key
        self.n_perturbs = n_perturbs
        self.nmf_iters = nmf_iters
        self.epsilon = epsilon
        self.statistic = statistic
        self.use_kernel = use_kernel

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        tracer = get_tracer()
        padded, k_pad, n_real = self._pad_ks(ks)
        # "fit" brackets the fused fit+score dispatch (one jit'd ensemble);
        # "score" brackets device->host sync of the silhouette statistics.
        with tracer.span("fit", track="device:0", kind="nmfk",
                         ks=[int(k) for k in ks], batch=len(padded), k_pad=k_pad):
            sc = nmfk_score_batched(
                self.v,
                padded,
                self.key,
                k_pad=k_pad,
                n_perturbs=self.n_perturbs,
                nmf_iters=self.nmf_iters,
                epsilon=self.epsilon,
                use_kernel=self.use_kernel,
            )
            scores = sc.min_silhouette if self.statistic == "min" else sc.mean_silhouette
        with tracer.span("score", track="device:0", kind="nmfk", batch=len(padded)):
            return [float(s) for s in scores[:n_real]]


class KMeansBatchPlane(_BatchPlaneBase):
    """K-Means Davies-Bouldin (minimize) or silhouette (maximize) per wave.

    Lane i reproduces ``kmeans(x, ks[i], fold_in(key, ks[i]))`` exactly
    (masked fits are draw-for-draw identical to per-k fits), so this plane
    matches a threaded K-Means evaluator score-for-score.
    """

    def __init__(
        self,
        x: Array,
        key: Array,
        score: str = "davies_bouldin",
        max_iters: int = 100,
        k_pad: int | None = None,
        pad_batch: bool = True,
        use_kernel: bool = False,
    ):
        super().__init__(k_pad, pad_batch)
        if score not in ("davies_bouldin", "silhouette"):
            raise ValueError(f"score must be 'davies_bouldin' or 'silhouette', got {score!r}")
        self.x = x
        self.key = key
        self.score = score
        self.max_iters = max_iters
        self.use_kernel = use_kernel

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        from repro.core.scoring import davies_bouldin_score_masked, silhouette_score_masked

        tracer = get_tracer()
        padded, k_pad, n_real = self._pad_ks(ks)
        with tracer.span("fit", track="device:0", kind="kmeans",
                         ks=[int(k) for k in ks], batch=len(padded), k_pad=k_pad):
            res = kmeans_batched(self.x, padded, self.key, k_pad=k_pad, max_iters=self.max_iters)
        ks_arr = jnp.asarray(padded)
        cluster_mask = jnp.arange(k_pad)[None, :] < ks_arr[:, None]  # (b, k_pad)
        # x stays unbatched (n, d): the jnp scorer tiers broadcast it against
        # the batched labels so the point-pairwise work is done once, while
        # the Pallas tier streams per-lane tiles that never hit HBM.
        with tracer.span("score", track="device:0", kind=self.score, batch=len(padded)):
            if self.score == "davies_bouldin":
                scores = davies_bouldin_score_masked(
                    self.x, res.labels, k_pad, cluster_mask=cluster_mask
                )
            else:
                scores = silhouette_score_masked(
                    self.x, res.labels, k_pad, use_kernel=self.use_kernel
                )
            return [float(s) for s in scores[:n_real]]


__all__ = ["NMFkBatchPlane", "KMeansBatchPlane"]
