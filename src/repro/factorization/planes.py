"""Batched evaluation planes: mask-padded multi-k fits behind ``EvalPlane``.

These are the hardware-shaped back ends of the wavefront executor
(``repro.core.evalplane.WavefrontScheduler``): a whole frontier of k values
becomes ONE vmapped, jit'd fit at a common padded rank, so the per-k
trace/JIT/dispatch cost the thread path pays |wave| times is paid once.

Two dispatch modes, selected by the ``mesh=`` option:

  * **single-device** (``mesh=None``, default): the padded wave runs as one
    vmapped fit on the default device — PR 1's batched executor.
  * **mesh-sharded**: a 2-D ``Mesh((lane, data))`` splits the wave's k axis
    over the ``lane`` axis (each device group fits a disjoint slice of the
    padded ensemble via shard_map) and, for the NMFk plane, optionally
    shards V's rows over the ``data`` axis reusing the pyDNMFk psum
    structure — the paper's parallel-over-k × distributed-within-k
    composition inside one jit'd dispatch. Build the mesh with
    ``repro.launch.mesh.make_wave_mesh``.

Shape discipline (what keeps compile counts ~O(1) instead of O(|K|)):

  * the rank axis is padded to a fixed ``k_pad`` (default: the largest k
    the plane will ever see — pass the top of the search range);
  * the batch axis is bucketed by ``repro.factorization.batching.
    bucket_batch``: pow2 rounding with a floor of ``bucket_min`` (defaults
    to the mesh lane count so every dispatch splits evenly over lanes),
    and **reuse of already-compiled buckets** — a scalar fallback or an
    odd-sized wave rides the nearest compiled ``(batch, k_pad)`` shape
    instead of minting its own. ``WavefrontScheduler(max_wave=N)`` sets the
    plane's ``dispatch_cap`` so padding never exceeds an explicit memory
    bound; ``pad_batch=False`` disables pow2 bucketing (lane-multiple
    padding still applies under a mesh).

``shapes_compiled`` records the distinct (batch, k_pad) shapes dispatched —
a deterministic proxy for jit compilations that the wavefront benchmarks
compare against the thread path's one-compilation-per-distinct-k.

Telemetry: every dispatch observes ``lane_utilization`` (real lanes /
dispatched lanes) and, under a mesh, emits per-device-group ``lane`` spans
on ``device:{i}`` tracks so a Perfetto trace shows which ks each lane group
carried through the wave.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.obs import get_metrics, get_tracer

from .batching import bucket_batch, round_up_multiple
from .kmeans import kmeans_batched
from .nmfk import nmfk_score_batched, nmfk_score_sharded

Array = jax.Array


class _BatchPlaneBase:
    """Shared padding / bucketing / accounting for the batched planes."""

    def __init__(
        self,
        k_pad: int | None,
        pad_batch: bool,
        mesh=None,
        lane_axis: str = "lane",
        data_axis: str = "data",
        bucket_min: int | None = None,
        comm: str = "sync",
    ):
        from .distributed import COMM_MODES

        if comm not in COMM_MODES:
            raise ValueError(f"comm must be one of {COMM_MODES}, got {comm!r}")
        self.k_pad = k_pad
        self.pad_batch = pad_batch
        self.mesh = mesh
        self.comm = comm
        self.lane_axis = lane_axis
        self.data_axis = data_axis
        shape = dict(mesh.shape) if mesh is not None else {}
        if mesh is not None and lane_axis not in shape:
            raise ValueError(f"mesh {mesh} has no {lane_axis!r} axis")
        self.lane_count = shape.get(lane_axis, 1)
        self.data_count = shape.get(data_axis, 1)
        # pow2 floor: pad small waves up to one full lane sweep so every
        # wave size below the lane count shares a single compiled shape
        self.bucket_min = bucket_min if bucket_min is not None else max(self.lane_count, 1)
        # dispatch cap (number of lanes per batch). WavefrontScheduler sets
        # this to its max_wave so batch padding never exceeds the
        # device-memory bound the cap was chosen for.
        self.dispatch_cap: int | None = None
        self.n_dispatches = 0
        self.n_evals = 0
        self.shapes_compiled: set[tuple[int, int]] = set()
        self.last_lane_utilization: float | None = None

    # -- padding ----------------------------------------------------------------
    def _pad_ks(self, ks: Sequence[int]) -> tuple[list[int], int, int]:
        ks = [int(k) for k in ks]
        if not ks:
            raise ValueError("evaluate_batch needs at least one k")
        k_pad = self.k_pad if self.k_pad is not None else max(ks)
        if k_pad < max(ks):
            raise ValueError(f"plane k_pad={k_pad} smaller than requested k={max(ks)}")
        n_real = len(ks)
        if self.pad_batch:
            target = bucket_batch(
                n_real,
                lanes=self.lane_count,
                bucket_min=self.bucket_min,
                cap=self.dispatch_cap,
                compiled=(b for b, kp in self.shapes_compiled if kp == k_pad),
            )
        elif self.lane_count > 1:
            # no pow2 bucketing, but a sharded dispatch must still split
            # evenly over the mesh's lane axis
            target = round_up_multiple(n_real, self.lane_count)
        else:
            target = n_real
        ks = ks + [ks[0]] * (target - n_real)
        self.n_dispatches += 1
        self.n_evals += n_real
        util = n_real / len(ks)
        self.last_lane_utilization = util
        get_metrics().observe("lane_utilization", util)
        shape = (len(ks), k_pad)
        if shape not in self.shapes_compiled:
            # new padded shape == a jit cache miss on the next dispatch: the
            # batched fits are compiled per (batch, k_pad), so recompiles
            # become visible in the trace instead of silent wall-clock.
            self.shapes_compiled.add(shape)
            get_metrics().inc("compile_count")
            get_tracer().event(
                "compile", track=self._dispatch_track(), batch=shape[0], k_pad=shape[1],
                lanes=self.lane_count, data=self.data_count,
            )
        return ks, k_pad, n_real

    # -- telemetry ---------------------------------------------------------------
    def _dispatch_track(self) -> str:
        return "device:all" if self.mesh is not None else "device:0"

    def _emit_lane_spans(
        self, tracer, t0_us: float, padded: list[int], n_real: int, kind: str
    ) -> None:
        """Retroactive per-device-group spans: lane group i carried the
        contiguous slice padded[i*per:(i+1)*per] for the whole dispatch."""
        if self.mesh is None or self.lane_count <= 1 or not tracer.enabled:
            return
        dur = max(tracer.now_us() - t0_us, 0.0)
        per = len(padded) // self.lane_count
        for i in range(self.lane_count):
            lane_ks = padded[i * per : (i + 1) * per]
            real = max(0, min(n_real - i * per, per))
            tracer.add_span(
                "lane", t0_us, dur, track=f"device:{i}",
                kind=kind, ks=lane_ks, n_real=real, data_shards=self.data_count,
            )

    # chunk size of the abortable scalar path; per-chunk sweep counts land
    # in ``last_scalar_sweeps`` (the abort regression test's probe)
    abort_chunk = 25
    last_scalar_sweeps: int | None = None

    def evaluate_one(self, k: int, should_abort=None) -> float:
        # Without an abort callback: one fused dispatch (bucketing reuses
        # the nearest already-compiled (batch, k_pad) shape rather than
        # compiling a batch-of-one executable). With one, route through the
        # subclass's chunked scalar path so §III-D prunes landing mid-fit
        # actually stop the sweeps — the batched planes used to discard the
        # callback entirely.
        if should_abort is not None:
            return self._evaluate_one_chunked(k, should_abort)
        return self.evaluate_batch([k])[0]

    def _evaluate_one_chunked(self, k: int, should_abort) -> float:
        # fallback for planes without a resumable fit: poll once up front
        # (a k pruned before dispatch costs nothing), then run the fused fit
        if should_abort():
            return float("nan")
        return self.evaluate_batch([k])[0]


class NMFkBatchPlane(_BatchPlaneBase):
    """NMFk stability scoring of a whole wave as one padded vmapped ensemble.

    Per-lane RNG is ``fold_in(key, k)`` — the same schedule as
    ``make_nmfk_evaluator`` — so the batched and threaded executors agree
    on the score landscape (exactly at k == k_pad, to init-draw noise
    below it).

    With ``mesh=`` the ensemble is shard_map'd: k-lanes split over the
    ``lane`` axis; if the mesh's ``data`` axis is non-trivial, V's rows are
    additionally sharded and each fit runs the distributed psum structure
    (requires ``v.shape[0]`` divisible by the data-axis size).
    ``comm="pipelined"`` switches those data-sharded fits to the
    decomposed-psum schedule that overlaps the Gram reductions with the
    local W-update; each such dispatch publishes an ``overlap_fraction``
    gauge and (when tracing) modeled per-sweep comm/compute spans.
    """

    def __init__(
        self,
        v: Array,
        key: Array,
        n_perturbs: int = 8,
        nmf_iters: int = 150,
        epsilon: float = 0.015,
        statistic: str = "min",
        k_pad: int | None = None,
        pad_batch: bool = True,
        use_kernel: bool = False,
        mesh=None,
        lane_axis: str = "lane",
        data_axis: str = "data",
        bucket_min: int | None = None,
        comm: str = "sync",
    ):
        super().__init__(k_pad, pad_batch, mesh, lane_axis, data_axis, bucket_min, comm)
        if statistic not in ("min", "mean"):
            raise ValueError(f"statistic must be 'min' or 'mean', got {statistic!r}")
        if self.data_count > 1 and v.shape[0] % self.data_count:
            raise ValueError(
                f"v rows {v.shape[0]} not divisible by data-axis size {self.data_count}"
            )
        self.v = v
        self.key = key
        self.n_perturbs = n_perturbs
        self.nmf_iters = nmf_iters
        self.epsilon = epsilon
        self.statistic = statistic
        self.use_kernel = use_kernel

    def _score_wave(self, padded: Sequence[int], k_pad: int):
        if self.mesh is not None:
            return nmfk_score_sharded(
                self.v, padded, self.key, self.mesh,
                k_pad=k_pad, n_perturbs=self.n_perturbs, nmf_iters=self.nmf_iters,
                epsilon=self.epsilon, use_kernel=self.use_kernel,
                lane_axis=self.lane_axis, data_axis=self.data_axis, comm=self.comm,
            )
        return nmfk_score_batched(
            self.v, padded, self.key,
            k_pad=k_pad, n_perturbs=self.n_perturbs, nmf_iters=self.nmf_iters,
            epsilon=self.epsilon, use_kernel=self.use_kernel,
        )

    def _evaluate_one_chunked(self, k: int, should_abort) -> float:
        """Scalar NMFk with §III-D abort polling at chunk boundaries.

        Runs the k's perturbation ensemble as cold elastic lanes advanced
        ``abort_chunk`` sweeps per dispatch (draw-for-draw and
        sweep-for-sweep identical to the fused batch fit when it runs to
        completion — the elastic kernels share ``_masked_sweeps``). If the
        abort fires between chunks, the remaining sweeps are never paid and
        the partial ensemble is scored as-is: Binary Bleed pruned this k,
        so its score only matters for accounting, never for ``k_optimal``
        (pruning soundness). Aborts before the first chunk return NaN — a
        void score no threshold test selects. Single-device by design: the
        scalar path is the thread executor's, not the mesh's.
        """
        from .nmfk import (
            elastic_chunk,
            elastic_lane_init,
            elastic_lane_keys,
            elastic_pooled_score,
        )

        k = int(k)
        k_pad = self.k_pad if self.k_pad is not None else k
        P = self.n_perturbs
        kj = jnp.asarray(k)
        pkeys, fkeys = elastic_lane_keys(self.key, k, P)
        pairs = [
            elastic_lane_init(self.v, kj, pkeys[p], fkeys[p], k_pad, self.epsilon)
            for p in range(P)
        ]
        w = jnp.stack([p[0] for p in pairs])
        h = jnp.stack([p[1] for p in pairs])
        keff = jnp.full((P,), k, jnp.int32)
        done = 0
        errs = None
        self.last_scalar_sweeps = 0
        while done < self.nmf_iters:
            if should_abort():
                break
            step = min(self.abort_chunk, self.nmf_iters - done)
            steps = jnp.full((P,), step, jnp.int32)
            w, h, errs = elastic_chunk(
                self.v, w, h, keff, steps, pkeys, k_pad, self.abort_chunk,
                self.epsilon, use_kernel=self.use_kernel,
            )
            done += step
            self.last_scalar_sweeps = done * P
        if errs is None:
            return float("nan")
        sc = elastic_pooled_score(w, errs, kj, k_pad, P, self.use_kernel)
        return float(sc.min_silhouette if self.statistic == "min" else sc.mean_silhouette)

    _MAX_TRACE_SWEEPS = 16  # per-sweep modeled spans emitted per dispatch

    def _emit_overlap_telemetry(self, tracer, t0_us: float, k_pad: int) -> None:
        """Publish the pipelined schedule's comm/compute overlap.

        The sweeps live inside one jit'd fori_loop, so per-sweep timing is
        not host-observable; spans are *modeled* — the measured dispatch
        wall time apportioned uniformly over sweeps, comm span lengths from
        ``overlap_model`` — and marked as such. The ``overlap_fraction``
        gauge (share of per-sweep comm hidden behind the local W-update) is
        always published; spans only when tracing is on.
        """
        if self.comm != "pipelined" or self.data_count <= 1:
            return
        from .distributed import overlap_model

        model = overlap_model(self.v.shape[0], self.v.shape[1], k_pad, self.data_count)
        get_metrics().set_gauge("overlap_fraction", model["overlap_fraction"])
        get_metrics().observe("overlap_fraction_hist", model["overlap_fraction"])
        if not tracer.enabled:
            return
        dur = max(tracer.now_us() - t0_us, 0.0)
        sweeps = min(self.nmf_iters, self._MAX_TRACE_SWEEPS)
        per = dur / max(self.nmf_iters, 1)
        comm_dur = per * model["comm_fraction"]
        for i in range(sweeps):
            t = t0_us + i * per
            tracer.add_span(
                "sweep_compute", t, per, track="data:compute",
                sweep=i, modeled=True, data_shards=self.data_count,
            )
            tracer.add_span(
                "gram_ring", t, comm_dur, track="data:comm",
                sweep=i, modeled=True,
                overlap_fraction=model["overlap_fraction"],
            )

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        tracer = get_tracer()
        padded, k_pad, n_real = self._pad_ks(ks)
        t0_us = tracer.now_us()
        # "fit" brackets the fused fit+score dispatch (one jit'd ensemble);
        # "score" brackets device->host sync of the silhouette statistics.
        with tracer.span("fit", track=self._dispatch_track(), kind="nmfk",
                         ks=[int(k) for k in ks], batch=len(padded), k_pad=k_pad,
                         comm=self.comm):
            sc = self._score_wave(padded, k_pad)
            scores = sc.min_silhouette if self.statistic == "min" else sc.mean_silhouette
        with tracer.span("score", track=self._dispatch_track(), kind="nmfk", batch=len(padded)):
            out = [float(s) for s in scores[:n_real]]
        self._emit_lane_spans(tracer, t0_us, padded, n_real, kind="nmfk")
        self._emit_overlap_telemetry(tracer, t0_us, k_pad)
        return out


class KMeansBatchPlane(_BatchPlaneBase):
    """K-Means Davies-Bouldin (minimize) or silhouette (maximize) per wave.

    Lane i reproduces ``kmeans(x, ks[i], fold_in(key, ks[i]))`` exactly
    (masked fits are draw-for-draw identical to per-k fits), so this plane
    matches a threaded K-Means evaluator score-for-score.

    ``mesh=`` shards the wave's k axis over the mesh's ``lane`` axis; the
    data matrix stays replicated (K-Means assignment has no pyDNMFk-style
    Gram psum structure to reuse — a data axis of size > 1 is rejected).
    ``comm`` is accepted for executor-matrix uniformity but is a no-op:
    a lane-only dispatch has no cross-shard collectives to pipeline, so
    ``"pipelined"`` is bit-identical to ``"sync"`` here.
    """

    def __init__(
        self,
        x: Array,
        key: Array,
        score: str = "davies_bouldin",
        max_iters: int = 100,
        k_pad: int | None = None,
        pad_batch: bool = True,
        use_kernel: bool = False,
        mesh=None,
        lane_axis: str = "lane",
        data_axis: str = "data",
        bucket_min: int | None = None,
        comm: str = "sync",
    ):
        super().__init__(k_pad, pad_batch, mesh, lane_axis, data_axis, bucket_min, comm)
        if score not in ("davies_bouldin", "silhouette"):
            raise ValueError(f"score must be 'davies_bouldin' or 'silhouette', got {score!r}")
        if self.data_count > 1:
            raise ValueError("KMeansBatchPlane supports lane-only meshes (data axis must be 1)")
        self.x = x
        self.key = key
        self.score = score
        self.max_iters = max_iters
        self.use_kernel = use_kernel
        self._sharded_fns: dict[int, object] = {}

    def _sharded_fn(self, k_pad: int):
        """Jitted shard_map'd fit+score for this plane's mesh (per k_pad)."""
        fn = self._sharded_fns.get(k_pad)
        if fn is not None:
            return fn
        from jax.sharding import PartitionSpec as P

        from repro.core.scoring import davies_bouldin_score_masked, silhouette_score_masked

        from .distributed import shard_map
        from .kmeans import _kmeans_masked

        score, max_iters, use_kernel = self.score, self.max_iters, self.use_kernel
        lane = self.lane_axis

        def body(ks_l, keys_l, x):
            res = jax.vmap(
                lambda k_eff, sub: _kmeans_masked(x, k_eff, sub, k_pad, max_iters)
            )(ks_l, keys_l)
            if score == "davies_bouldin":
                cluster_mask = jnp.arange(k_pad)[None, :] < ks_l[:, None]
                return davies_bouldin_score_masked(
                    x, res.labels, k_pad, cluster_mask=cluster_mask
                )
            return silhouette_score_masked(x, res.labels, k_pad, use_kernel=use_kernel)

        fn = jax.jit(shard_map(
            body, self.mesh,
            in_specs=(P(lane), P(lane, None), P()),
            out_specs=P(lane),
            check_rep=False,  # scores replicated only over trivial axes; RNG defeats inference
        ))
        self._sharded_fns[k_pad] = fn
        return fn

    def _evaluate_one_chunked(self, k: int, should_abort) -> float:
        """Scalar K-Means with abort polling between Lloyd chunks.

        Chunking is bitwise-free here: the resumable ``_kmeans_masked_chunk``
        halts on exactly the convergence condition the fused while_loop
        uses, so an unaborted chunked fit reproduces the batch fit's
        centroids; the host stops early when delta clears tol. Aborts
        before the first chunk return NaN (void score).
        """
        from repro.core.scoring import davies_bouldin_score_masked, silhouette_score_masked

        from .kmeans import (
            _kmeans_masked_assign,
            _kmeans_masked_chunk,
            _kmeans_masked_init,
        )

        k = int(k)
        k_pad = self.k_pad if self.k_pad is not None else k
        sub = jax.random.fold_in(self.key, k)
        kj = jnp.asarray(k)
        centers = _kmeans_masked_init(self.x, kj, sub, k_pad)
        it = 0
        ran = False
        self.last_scalar_sweeps = 0
        while it < self.max_iters:
            if should_abort():
                break
            chunk = min(self.abort_chunk, self.max_iters - it)
            centers, delta, did = _kmeans_masked_chunk(self.x, centers, kj, k_pad, chunk)
            it += int(did)
            ran = True
            self.last_scalar_sweeps = it
            if float(delta) <= 1e-6:
                break
        if not ran:
            return float("nan")
        labels, _ = _kmeans_masked_assign(self.x, centers, kj, k_pad)
        if self.score == "davies_bouldin":
            cluster_mask = (jnp.arange(k_pad) < kj)[None, :]
            scores = davies_bouldin_score_masked(
                self.x, labels[None], k_pad, cluster_mask=cluster_mask
            )
        else:
            scores = silhouette_score_masked(
                self.x, labels[None], k_pad, use_kernel=self.use_kernel
            )
        return float(scores[0])

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        from repro.core.scoring import davies_bouldin_score_masked, silhouette_score_masked

        from .batching import batched_lanes

        tracer = get_tracer()
        padded, k_pad, n_real = self._pad_ks(ks)
        t0_us = tracer.now_us()
        if self.mesh is not None:
            with tracer.span("fit", track=self._dispatch_track(), kind="kmeans",
                             ks=[int(k) for k in ks], batch=len(padded), k_pad=k_pad):
                ks_arr, keys, k_pad = batched_lanes(padded, self.key, k_pad)
                scores = self._sharded_fn(k_pad)(ks_arr, keys, self.x)
            with tracer.span("score", track=self._dispatch_track(), kind=self.score,
                             batch=len(padded)):
                out = [float(s) for s in scores[:n_real]]
            self._emit_lane_spans(tracer, t0_us, padded, n_real, kind="kmeans")
            return out
        with tracer.span("fit", track=self._dispatch_track(), kind="kmeans",
                         ks=[int(k) for k in ks], batch=len(padded), k_pad=k_pad):
            res = kmeans_batched(self.x, padded, self.key, k_pad=k_pad, max_iters=self.max_iters)
        ks_arr = jnp.asarray(padded)
        cluster_mask = jnp.arange(k_pad)[None, :] < ks_arr[:, None]  # (b, k_pad)
        # x stays unbatched (n, d): the jnp scorer tiers broadcast it against
        # the batched labels so the point-pairwise work is done once, while
        # the Pallas tier streams per-lane tiles that never hit HBM.
        with tracer.span("score", track=self._dispatch_track(), kind=self.score,
                         batch=len(padded)):
            if self.score == "davies_bouldin":
                scores = davies_bouldin_score_masked(
                    self.x, res.labels, k_pad, cluster_mask=cluster_mask
                )
            else:
                scores = silhouette_score_masked(
                    self.x, res.labels, k_pad, use_kernel=self.use_kernel
                )
            return [float(s) for s in scores[:n_real]]


# ---------------------------------------------------------------------------
# elastic plane: continuous batching of (k, perturbation) fit-chunks
# ---------------------------------------------------------------------------
import dataclasses
from collections import deque


@dataclasses.dataclass
class _Lane:
    """One occupied slot: a single perturbation fit of a single k."""

    k: int
    p: int
    done: int = 0  # MU sweeps applied so far
    prev_err: float = float("inf")  # rel_error at the previous chunk boundary


@dataclasses.dataclass
class _KTask:
    """Host-side lifecycle of one submitted k (its P perturbation lanes)."""

    pkeys: Array  # (P, 2) perturbation-noise keys
    fkeys: Array  # (P, 2) init keys
    w_parts: dict = dataclasses.field(default_factory=dict)  # p -> (n, k_pad) W
    errs: dict = dataclasses.field(default_factory=dict)  # p -> final rel_error
    cancelled: bool = False
    scored: bool = False


class NMFkElasticPlane:
    """Convergence-gated chunked NMFk fits over a fixed pool of lane slots.

    The unit of dispatch is a *chunk* — ``chunk`` masked MU sweeps of every
    occupied lane, one jit'd vmapped (or shard_map'd) call at a fixed
    padded shape — instead of a whole wave of fixed-iteration fits. One
    lane is one (k, perturbation) fit. Between chunks, host-side:

      * **convergence gate** — a lane retires when its rel_error improved
        by less than ``tol`` over the last chunk (or its sweep budget
        ``nmf_iters`` is exhausted); the sweeps it didn't run are counted
        as ``sweeps_saved``;
      * **lane refill** — freed slots immediately drain queued
        (k, perturbation) lanes submitted by the scheduler, so the batch
        stays full while ks enter and leave at their own pace
        (continuous batching applied to the k-search);
      * **warm starts** — a refilled lane seeds its W from the nearest
        completed k's factors via ``elastic_lane_warm_init`` (column
        pad/truncate + re-normalize; cold ``nmf_init``-style draw when the
        ``WarmStartCache`` has nothing within its window);
      * **eviction** — ``cancel(k)`` (the scheduler's reaction to a Binary
        Bleed prune) removes queued lanes and evicts in-flight ones
        mid-fit, crediting their remaining sweeps to ``sweeps_saved`` —
        §III-D abort made first-class.

    ``tol <= 0`` disables the gate: every lane runs exactly ``nmf_iters``
    sweeps and (with ``warm_start=False``) reproduces the fixed-iteration
    batched plane draw-for-draw — the oracle the conformance tests tighten
    ``tol`` toward. Accounting invariant (checked by the elastic bench):
    ``sweeps_run + sweeps_saved == sweeps_fixed_total`` over any completed
    search, where ``sweeps_fixed_total`` counts ``n_perturbs * nmf_iters``
    for every submitted k.

    Occupied slots are kept compacted in a prefix (retirement swaps the
    last occupied lane into the freed slot), and each dispatch runs the
    bucketed prefix (``bucket_batch`` pow2 policy), so compiled shapes stay
    O(log slots). Per-lane sweep budgets ride the traced ``steps`` vector —
    a lane near its budget trims its final chunk inside the same compiled
    shape.
    """

    def __init__(
        self,
        v: Array,
        key: Array,
        n_perturbs: int = 8,
        nmf_iters: int = 150,
        epsilon: float = 0.015,
        statistic: str = "min",
        k_pad: int | None = None,
        tol: float = 1e-3,
        chunk: int = 25,
        slots: int | None = None,
        warm_start: bool = True,
        warm_window: int = 8,
        use_kernel: bool = False,
        mesh=None,
        lane_axis: str = "lane",
        data_axis: str = "data",
        comm: str = "sync",
    ):
        from .batching import WarmStartCache, next_pow2
        from .distributed import COMM_MODES

        if statistic not in ("min", "mean"):
            raise ValueError(f"statistic must be 'min' or 'mean', got {statistic!r}")
        if comm not in COMM_MODES:
            raise ValueError(f"comm must be one of {COMM_MODES}, got {comm!r}")
        if k_pad is None:
            raise ValueError("NMFkElasticPlane needs an explicit k_pad (slots persist across ks)")
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        shape = dict(mesh.shape) if mesh is not None else {}
        if mesh is not None and lane_axis not in shape:
            raise ValueError(f"mesh {mesh} has no {lane_axis!r} axis")
        self.lane_count = shape.get(lane_axis, 1)
        self.data_count = shape.get(data_axis, 1)
        if self.data_count > 1 and v.shape[0] % self.data_count:
            raise ValueError(
                f"v rows {v.shape[0]} not divisible by data-axis size {self.data_count}"
            )
        if slots is None:
            slots = round_up_multiple(next_pow2(max(2 * n_perturbs, self.lane_count)), self.lane_count)
        if slots < 1 or slots % max(self.lane_count, 1):
            raise ValueError(f"slots={slots} must be a positive multiple of lane count {self.lane_count}")
        self.v = v
        self.key = key
        self.n_perturbs = int(n_perturbs)
        self.nmf_iters = int(nmf_iters)
        self.epsilon = float(epsilon)
        self.statistic = statistic
        self.k_pad = int(k_pad)
        self.tol = float(tol)
        self.chunk = int(chunk)
        self.slots = int(slots)
        self.warm_start = bool(warm_start)
        self.use_kernel = bool(use_kernel)
        self.mesh = mesh
        self.lane_axis = lane_axis
        self.data_axis = data_axis
        self.comm = comm
        self.warm_cache = WarmStartCache(window=warm_window)

        n, m = v.shape
        self._w = jnp.zeros((self.slots, n, self.k_pad), v.dtype)
        self._h = jnp.zeros((self.slots, self.k_pad, m), v.dtype)
        self._keff = jnp.zeros((self.slots,), jnp.int32)
        self._pkeys = jnp.zeros((self.slots, 2), jnp.uint32)
        self._slot: list[_Lane | None] = [None] * self.slots
        self._n_occ = 0
        self._queue: deque[tuple[int, int]] = deque()
        self._tasks: dict[int, _KTask] = {}
        self._ready: list[tuple[int, float]] = []

        # accounting (the bench's invariant: run + saved == fixed_total)
        self.sweeps_run = 0
        self.sweeps_saved = 0
        self.sweeps_fixed_total = 0
        self.n_ticks = 0
        self.shapes_compiled: set[tuple[int, int]] = set()
        self.last_lane_occupancy: float | None = None
        self.last_lane_utilization: float | None = None  # alias for scheduler gauges

    # -- scheduler surface -------------------------------------------------------
    @property
    def backlog(self) -> int:
        """Queued lanes not yet slotted (admission signal for the refiller)."""
        return len(self._queue)

    @property
    def idle(self) -> bool:
        return not self._queue and self._n_occ == 0 and not self._ready

    def inflight_ks(self) -> set[int]:
        """ks submitted but not yet scored or cancelled."""
        return {
            k for k, t in self._tasks.items() if not t.scored and not t.cancelled
        }

    def submit(self, k: int) -> None:
        """Enqueue the P perturbation lanes of k (slotted by the next tick)."""
        from .nmfk import elastic_lane_keys

        k = int(k)
        if k > self.k_pad:
            raise ValueError(f"k={k} exceeds plane k_pad={self.k_pad}")
        if k in self._tasks:
            raise ValueError(f"k={k} already submitted")
        pkeys, fkeys = elastic_lane_keys(self.key, k, self.n_perturbs)
        self._tasks[k] = _KTask(pkeys=pkeys, fkeys=fkeys)
        for p in range(self.n_perturbs):
            self._queue.append((k, p))
        self.sweeps_fixed_total += self.n_perturbs * self.nmf_iters
        get_metrics().inc("sweeps_fixed_total", self.n_perturbs * self.nmf_iters)

    def cancel(self, k: int) -> bool:
        """Evict k mid-flight (Binary Bleed pruned it): dequeue its pending
        lanes and free its occupied slots, crediting unspent sweeps."""
        k = int(k)
        task = self._tasks.get(k)
        if task is None or task.scored or task.cancelled:
            return False
        task.cancelled = True
        pending = sum(1 for kk, _ in self._queue if kk == k)
        if pending:
            self._queue = deque((kk, p) for kk, p in self._queue if kk != k)
            self._credit_saved(pending * self.nmf_iters)
        evicted = 0
        for i in range(self._n_occ - 1, -1, -1):
            lane = self._slot[i]
            if lane is not None and lane.k == k:
                self._credit_saved(self.nmf_iters - lane.done)
                self._free_slot(i)
                evicted += 1
        get_tracer().event("evict", track=self._dispatch_track(), k=k,
                           pending=pending, evicted=evicted)
        return True

    def tick(self) -> list[tuple[int, float]]:
        """Refill freed slots, advance every occupied lane one chunk, retire
        converged / budget-exhausted lanes; returns newly scored (k, score)."""
        tracer = get_tracer()
        metrics = get_metrics()
        self._refill()
        if self._n_occ == 0:
            out, self._ready = self._ready, []
            return out
        self.n_ticks += 1
        n_occ = self._n_occ
        batch = bucket_batch(
            n_occ, lanes=self.lane_count, bucket_min=min(self.lane_count, self.slots),
            cap=self.slots,
            compiled=(b for b, kp in self.shapes_compiled if kp == self.k_pad),
        )
        shape = (batch, self.k_pad)
        if shape not in self.shapes_compiled:
            self.shapes_compiled.add(shape)
            metrics.inc("compile_count")
            tracer.event("compile", track=self._dispatch_track(), batch=batch,
                         k_pad=self.k_pad, lanes=self.lane_count, data=self.data_count)
        steps_host = [
            min(self.chunk, self.nmf_iters - self._slot[i].done) if i < n_occ else 0
            for i in range(batch)
        ]
        occupancy = n_occ / batch
        self.last_lane_occupancy = occupancy
        self.last_lane_utilization = occupancy
        metrics.observe("lane_occupancy", occupancy)
        metrics.set_gauge("lane_occupancy", occupancy)
        with tracer.span(
            "chunk", track=self._dispatch_track(), kind="nmfk_elastic", batch=batch,
            n_occ=n_occ, k_pad=self.k_pad, sweeps=max(steps_host),
            ks=sorted({self._slot[i].k for i in range(n_occ)}),
        ):
            w_new, h_new, errs = self._dispatch(batch, jnp.asarray(steps_host, jnp.int32))
            errs_host = [float(e) for e in errs[:n_occ]]
        self._w = jnp.concatenate([w_new, self._w[batch:]], axis=0)
        self._h = jnp.concatenate([h_new, self._h[batch:]], axis=0)

        retire: list[int] = []
        for i in range(n_occ):
            lane = self._slot[i]
            st = steps_host[i]
            lane.done += st
            self.sweeps_run += st
            metrics.inc("sweeps_run", st)
            err = errs_host[i]
            converged = self.tol > 0 and (lane.prev_err - err) < self.tol
            lane.prev_err = err
            if converged or lane.done >= self.nmf_iters:
                if lane.done < self.nmf_iters:
                    self._credit_saved(self.nmf_iters - lane.done)
                retire.append(i)
        for i in sorted(retire, reverse=True):
            lane = self._slot[i]
            self._finish_lane(lane, self._w[i], errs_host[i])
            self._free_slot(i)
        out, self._ready = self._ready, []
        return out

    # -- internals ---------------------------------------------------------------
    def _dispatch_track(self) -> str:
        return "device:all" if self.mesh is not None else "device:0"

    def _credit_saved(self, sweeps: int) -> None:
        if sweeps > 0:
            self.sweeps_saved += sweeps
            get_metrics().inc("sweeps_saved", sweeps)

    def _dispatch(self, batch: int, steps: Array):
        from .nmfk import elastic_chunk, elastic_chunk_sharded

        w, h = self._w[:batch], self._h[:batch]
        keff, pkeys = self._keff[:batch], self._pkeys[:batch]
        if self.mesh is not None:
            return elastic_chunk_sharded(
                self.v, w, h, keff, steps, pkeys, self.mesh, self.k_pad, self.chunk,
                self.epsilon, use_kernel=self.use_kernel, lane_axis=self.lane_axis,
                data_axis=self.data_axis, comm=self.comm,
            )
        return elastic_chunk(
            self.v, w, h, keff, steps, pkeys, self.k_pad, self.chunk, self.epsilon,
            use_kernel=self.use_kernel,
        )

    def _refill(self) -> None:
        from .nmfk import elastic_lane_init, elastic_lane_warm_init

        metrics = get_metrics()
        while self._queue and self._n_occ < self.slots:
            k, p = self._queue.popleft()
            task = self._tasks[k]
            if task.cancelled:  # defensive: cancel() already dequeues
                continue
            kj = jnp.asarray(k)
            src = self.warm_cache.nearest(k, p) if self.warm_start else None
            if src is not None:
                k_src, w_src = src
                w0, h0 = elastic_lane_warm_init(
                    self.v, kj, task.pkeys[p], task.fkeys[p], w_src,
                    jnp.asarray(k_src), self.k_pad, self.epsilon,
                )
                metrics.inc("warm_start_hits")
                get_tracer().event("warm_start", track=self._dispatch_track(),
                                   k=k, p=p, k_src=int(k_src))
            else:
                w0, h0 = elastic_lane_init(
                    self.v, kj, task.pkeys[p], task.fkeys[p], self.k_pad, self.epsilon
                )
            i = self._n_occ
            self._w = self._w.at[i].set(w0)
            self._h = self._h.at[i].set(h0)
            self._keff = self._keff.at[i].set(k)
            self._pkeys = self._pkeys.at[i].set(task.pkeys[p])
            self._slot[i] = _Lane(k=k, p=p)
            self._n_occ += 1

    def _free_slot(self, i: int) -> None:
        """Compact: move the last occupied lane into freed slot i."""
        j = self._n_occ - 1
        if i != j:
            self._w = self._w.at[i].set(self._w[j])
            self._h = self._h.at[i].set(self._h[j])
            self._keff = self._keff.at[i].set(self._keff[j])
            self._pkeys = self._pkeys.at[i].set(self._pkeys[j])
            self._slot[i] = self._slot[j]
        self._slot[j] = None
        self._n_occ = j

    def _finish_lane(self, lane: _Lane, w_row: Array, err: float) -> None:
        from .nmfk import elastic_pooled_score

        task = self._tasks[lane.k]
        task.w_parts[lane.p] = w_row
        task.errs[lane.p] = err
        self.warm_cache.put(lane.k, lane.p, w_row)
        if len(task.w_parts) < self.n_perturbs or task.cancelled:
            return
        w_all = jnp.stack([task.w_parts[p] for p in range(self.n_perturbs)])
        errs = jnp.asarray(
            [task.errs[p] for p in range(self.n_perturbs)], self.v.dtype
        )
        sc = elastic_pooled_score(
            w_all, errs, jnp.asarray(lane.k), self.k_pad, self.n_perturbs,
            self.use_kernel,
        )
        score = float(sc.min_silhouette if self.statistic == "min" else sc.mean_silhouette)
        task.scored = True
        task.w_parts.clear()  # the warm cache holds what future ks need
        self._ready.append((lane.k, score))


__all__ = ["NMFkBatchPlane", "KMeansBatchPlane", "NMFkElasticPlane"]
