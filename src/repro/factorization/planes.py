"""Batched evaluation planes: mask-padded multi-k fits behind ``EvalPlane``.

These are the hardware-shaped back ends of the wavefront executor
(``repro.core.evalplane.WavefrontScheduler``): a whole frontier of k values
becomes ONE vmapped, jit'd fit at a common padded rank, so the per-k
trace/JIT/dispatch cost the thread path pays |wave| times is paid once.

Two dispatch modes, selected by the ``mesh=`` option:

  * **single-device** (``mesh=None``, default): the padded wave runs as one
    vmapped fit on the default device — PR 1's batched executor.
  * **mesh-sharded**: a 2-D ``Mesh((lane, data))`` splits the wave's k axis
    over the ``lane`` axis (each device group fits a disjoint slice of the
    padded ensemble via shard_map) and, for the NMFk plane, optionally
    shards V's rows over the ``data`` axis reusing the pyDNMFk psum
    structure — the paper's parallel-over-k × distributed-within-k
    composition inside one jit'd dispatch. Build the mesh with
    ``repro.launch.mesh.make_wave_mesh``.

Shape discipline (what keeps compile counts ~O(1) instead of O(|K|)):

  * the rank axis is padded to a fixed ``k_pad`` (default: the largest k
    the plane will ever see — pass the top of the search range);
  * the batch axis is bucketed by ``repro.factorization.batching.
    bucket_batch``: pow2 rounding with a floor of ``bucket_min`` (defaults
    to the mesh lane count so every dispatch splits evenly over lanes),
    and **reuse of already-compiled buckets** — a scalar fallback or an
    odd-sized wave rides the nearest compiled ``(batch, k_pad)`` shape
    instead of minting its own. ``WavefrontScheduler(max_wave=N)`` sets the
    plane's ``dispatch_cap`` so padding never exceeds an explicit memory
    bound; ``pad_batch=False`` disables pow2 bucketing (lane-multiple
    padding still applies under a mesh).

``shapes_compiled`` records the distinct (batch, k_pad) shapes dispatched —
a deterministic proxy for jit compilations that the wavefront benchmarks
compare against the thread path's one-compilation-per-distinct-k.

Telemetry: every dispatch observes ``lane_utilization`` (real lanes /
dispatched lanes) and, under a mesh, emits per-device-group ``lane`` spans
on ``device:{i}`` tracks so a Perfetto trace shows which ks each lane group
carried through the wave.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.obs import get_metrics, get_tracer

from .batching import bucket_batch, round_up_multiple
from .kmeans import kmeans_batched
from .nmfk import nmfk_score_batched, nmfk_score_sharded

Array = jax.Array


class _BatchPlaneBase:
    """Shared padding / bucketing / accounting for the batched planes."""

    def __init__(
        self,
        k_pad: int | None,
        pad_batch: bool,
        mesh=None,
        lane_axis: str = "lane",
        data_axis: str = "data",
        bucket_min: int | None = None,
        comm: str = "sync",
    ):
        from .distributed import COMM_MODES

        if comm not in COMM_MODES:
            raise ValueError(f"comm must be one of {COMM_MODES}, got {comm!r}")
        self.k_pad = k_pad
        self.pad_batch = pad_batch
        self.mesh = mesh
        self.comm = comm
        self.lane_axis = lane_axis
        self.data_axis = data_axis
        shape = dict(mesh.shape) if mesh is not None else {}
        if mesh is not None and lane_axis not in shape:
            raise ValueError(f"mesh {mesh} has no {lane_axis!r} axis")
        self.lane_count = shape.get(lane_axis, 1)
        self.data_count = shape.get(data_axis, 1)
        # pow2 floor: pad small waves up to one full lane sweep so every
        # wave size below the lane count shares a single compiled shape
        self.bucket_min = bucket_min if bucket_min is not None else max(self.lane_count, 1)
        # dispatch cap (number of lanes per batch). WavefrontScheduler sets
        # this to its max_wave so batch padding never exceeds the
        # device-memory bound the cap was chosen for.
        self.dispatch_cap: int | None = None
        self.n_dispatches = 0
        self.n_evals = 0
        self.shapes_compiled: set[tuple[int, int]] = set()
        self.last_lane_utilization: float | None = None

    # -- padding ----------------------------------------------------------------
    def _pad_ks(self, ks: Sequence[int]) -> tuple[list[int], int, int]:
        ks = [int(k) for k in ks]
        if not ks:
            raise ValueError("evaluate_batch needs at least one k")
        k_pad = self.k_pad if self.k_pad is not None else max(ks)
        if k_pad < max(ks):
            raise ValueError(f"plane k_pad={k_pad} smaller than requested k={max(ks)}")
        n_real = len(ks)
        if self.pad_batch:
            target = bucket_batch(
                n_real,
                lanes=self.lane_count,
                bucket_min=self.bucket_min,
                cap=self.dispatch_cap,
                compiled=(b for b, kp in self.shapes_compiled if kp == k_pad),
            )
        elif self.lane_count > 1:
            # no pow2 bucketing, but a sharded dispatch must still split
            # evenly over the mesh's lane axis
            target = round_up_multiple(n_real, self.lane_count)
        else:
            target = n_real
        ks = ks + [ks[0]] * (target - n_real)
        self.n_dispatches += 1
        self.n_evals += n_real
        util = n_real / len(ks)
        self.last_lane_utilization = util
        get_metrics().observe("lane_utilization", util)
        shape = (len(ks), k_pad)
        if shape not in self.shapes_compiled:
            # new padded shape == a jit cache miss on the next dispatch: the
            # batched fits are compiled per (batch, k_pad), so recompiles
            # become visible in the trace instead of silent wall-clock.
            self.shapes_compiled.add(shape)
            get_metrics().inc("compile_count")
            get_tracer().event(
                "compile", track=self._dispatch_track(), batch=shape[0], k_pad=shape[1],
                lanes=self.lane_count, data=self.data_count,
            )
        return ks, k_pad, n_real

    # -- telemetry ---------------------------------------------------------------
    def _dispatch_track(self) -> str:
        return "device:all" if self.mesh is not None else "device:0"

    def _emit_lane_spans(
        self, tracer, t0_us: float, padded: list[int], n_real: int, kind: str
    ) -> None:
        """Retroactive per-device-group spans: lane group i carried the
        contiguous slice padded[i*per:(i+1)*per] for the whole dispatch."""
        if self.mesh is None or self.lane_count <= 1 or not tracer.enabled:
            return
        dur = max(tracer.now_us() - t0_us, 0.0)
        per = len(padded) // self.lane_count
        for i in range(self.lane_count):
            lane_ks = padded[i * per : (i + 1) * per]
            real = max(0, min(n_real - i * per, per))
            tracer.add_span(
                "lane", t0_us, dur, track=f"device:{i}",
                kind=kind, ks=lane_ks, n_real=real, data_shards=self.data_count,
            )

    def evaluate_one(self, k: int, should_abort=None) -> float:
        # one fused dispatch; no chunk boundary to poll. Bucketing makes
        # this reuse the nearest already-compiled (batch, k_pad) shape
        # rather than compiling a batch-of-one executable.
        del should_abort
        return self.evaluate_batch([k])[0]


class NMFkBatchPlane(_BatchPlaneBase):
    """NMFk stability scoring of a whole wave as one padded vmapped ensemble.

    Per-lane RNG is ``fold_in(key, k)`` — the same schedule as
    ``make_nmfk_evaluator`` — so the batched and threaded executors agree
    on the score landscape (exactly at k == k_pad, to init-draw noise
    below it).

    With ``mesh=`` the ensemble is shard_map'd: k-lanes split over the
    ``lane`` axis; if the mesh's ``data`` axis is non-trivial, V's rows are
    additionally sharded and each fit runs the distributed psum structure
    (requires ``v.shape[0]`` divisible by the data-axis size).
    ``comm="pipelined"`` switches those data-sharded fits to the
    decomposed-psum schedule that overlaps the Gram reductions with the
    local W-update; each such dispatch publishes an ``overlap_fraction``
    gauge and (when tracing) modeled per-sweep comm/compute spans.
    """

    def __init__(
        self,
        v: Array,
        key: Array,
        n_perturbs: int = 8,
        nmf_iters: int = 150,
        epsilon: float = 0.015,
        statistic: str = "min",
        k_pad: int | None = None,
        pad_batch: bool = True,
        use_kernel: bool = False,
        mesh=None,
        lane_axis: str = "lane",
        data_axis: str = "data",
        bucket_min: int | None = None,
        comm: str = "sync",
    ):
        super().__init__(k_pad, pad_batch, mesh, lane_axis, data_axis, bucket_min, comm)
        if statistic not in ("min", "mean"):
            raise ValueError(f"statistic must be 'min' or 'mean', got {statistic!r}")
        if self.data_count > 1 and v.shape[0] % self.data_count:
            raise ValueError(
                f"v rows {v.shape[0]} not divisible by data-axis size {self.data_count}"
            )
        self.v = v
        self.key = key
        self.n_perturbs = n_perturbs
        self.nmf_iters = nmf_iters
        self.epsilon = epsilon
        self.statistic = statistic
        self.use_kernel = use_kernel

    def _score_wave(self, padded: Sequence[int], k_pad: int):
        if self.mesh is not None:
            return nmfk_score_sharded(
                self.v, padded, self.key, self.mesh,
                k_pad=k_pad, n_perturbs=self.n_perturbs, nmf_iters=self.nmf_iters,
                epsilon=self.epsilon, use_kernel=self.use_kernel,
                lane_axis=self.lane_axis, data_axis=self.data_axis, comm=self.comm,
            )
        return nmfk_score_batched(
            self.v, padded, self.key,
            k_pad=k_pad, n_perturbs=self.n_perturbs, nmf_iters=self.nmf_iters,
            epsilon=self.epsilon, use_kernel=self.use_kernel,
        )

    _MAX_TRACE_SWEEPS = 16  # per-sweep modeled spans emitted per dispatch

    def _emit_overlap_telemetry(self, tracer, t0_us: float, k_pad: int) -> None:
        """Publish the pipelined schedule's comm/compute overlap.

        The sweeps live inside one jit'd fori_loop, so per-sweep timing is
        not host-observable; spans are *modeled* — the measured dispatch
        wall time apportioned uniformly over sweeps, comm span lengths from
        ``overlap_model`` — and marked as such. The ``overlap_fraction``
        gauge (share of per-sweep comm hidden behind the local W-update) is
        always published; spans only when tracing is on.
        """
        if self.comm != "pipelined" or self.data_count <= 1:
            return
        from .distributed import overlap_model

        model = overlap_model(self.v.shape[0], self.v.shape[1], k_pad, self.data_count)
        get_metrics().set_gauge("overlap_fraction", model["overlap_fraction"])
        get_metrics().observe("overlap_fraction_hist", model["overlap_fraction"])
        if not tracer.enabled:
            return
        dur = max(tracer.now_us() - t0_us, 0.0)
        sweeps = min(self.nmf_iters, self._MAX_TRACE_SWEEPS)
        per = dur / max(self.nmf_iters, 1)
        comm_dur = per * model["comm_fraction"]
        for i in range(sweeps):
            t = t0_us + i * per
            tracer.add_span(
                "sweep_compute", t, per, track="data:compute",
                sweep=i, modeled=True, data_shards=self.data_count,
            )
            tracer.add_span(
                "gram_ring", t, comm_dur, track="data:comm",
                sweep=i, modeled=True,
                overlap_fraction=model["overlap_fraction"],
            )

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        tracer = get_tracer()
        padded, k_pad, n_real = self._pad_ks(ks)
        t0_us = tracer.now_us()
        # "fit" brackets the fused fit+score dispatch (one jit'd ensemble);
        # "score" brackets device->host sync of the silhouette statistics.
        with tracer.span("fit", track=self._dispatch_track(), kind="nmfk",
                         ks=[int(k) for k in ks], batch=len(padded), k_pad=k_pad,
                         comm=self.comm):
            sc = self._score_wave(padded, k_pad)
            scores = sc.min_silhouette if self.statistic == "min" else sc.mean_silhouette
        with tracer.span("score", track=self._dispatch_track(), kind="nmfk", batch=len(padded)):
            out = [float(s) for s in scores[:n_real]]
        self._emit_lane_spans(tracer, t0_us, padded, n_real, kind="nmfk")
        self._emit_overlap_telemetry(tracer, t0_us, k_pad)
        return out


class KMeansBatchPlane(_BatchPlaneBase):
    """K-Means Davies-Bouldin (minimize) or silhouette (maximize) per wave.

    Lane i reproduces ``kmeans(x, ks[i], fold_in(key, ks[i]))`` exactly
    (masked fits are draw-for-draw identical to per-k fits), so this plane
    matches a threaded K-Means evaluator score-for-score.

    ``mesh=`` shards the wave's k axis over the mesh's ``lane`` axis; the
    data matrix stays replicated (K-Means assignment has no pyDNMFk-style
    Gram psum structure to reuse — a data axis of size > 1 is rejected).
    ``comm`` is accepted for executor-matrix uniformity but is a no-op:
    a lane-only dispatch has no cross-shard collectives to pipeline, so
    ``"pipelined"`` is bit-identical to ``"sync"`` here.
    """

    def __init__(
        self,
        x: Array,
        key: Array,
        score: str = "davies_bouldin",
        max_iters: int = 100,
        k_pad: int | None = None,
        pad_batch: bool = True,
        use_kernel: bool = False,
        mesh=None,
        lane_axis: str = "lane",
        data_axis: str = "data",
        bucket_min: int | None = None,
        comm: str = "sync",
    ):
        super().__init__(k_pad, pad_batch, mesh, lane_axis, data_axis, bucket_min, comm)
        if score not in ("davies_bouldin", "silhouette"):
            raise ValueError(f"score must be 'davies_bouldin' or 'silhouette', got {score!r}")
        if self.data_count > 1:
            raise ValueError("KMeansBatchPlane supports lane-only meshes (data axis must be 1)")
        self.x = x
        self.key = key
        self.score = score
        self.max_iters = max_iters
        self.use_kernel = use_kernel
        self._sharded_fns: dict[int, object] = {}

    def _sharded_fn(self, k_pad: int):
        """Jitted shard_map'd fit+score for this plane's mesh (per k_pad)."""
        fn = self._sharded_fns.get(k_pad)
        if fn is not None:
            return fn
        from jax.sharding import PartitionSpec as P

        from repro.core.scoring import davies_bouldin_score_masked, silhouette_score_masked

        from .distributed import shard_map
        from .kmeans import _kmeans_masked

        score, max_iters, use_kernel = self.score, self.max_iters, self.use_kernel
        lane = self.lane_axis

        def body(ks_l, keys_l, x):
            res = jax.vmap(
                lambda k_eff, sub: _kmeans_masked(x, k_eff, sub, k_pad, max_iters)
            )(ks_l, keys_l)
            if score == "davies_bouldin":
                cluster_mask = jnp.arange(k_pad)[None, :] < ks_l[:, None]
                return davies_bouldin_score_masked(
                    x, res.labels, k_pad, cluster_mask=cluster_mask
                )
            return silhouette_score_masked(x, res.labels, k_pad, use_kernel=use_kernel)

        fn = jax.jit(shard_map(
            body, self.mesh,
            in_specs=(P(lane), P(lane, None), P()),
            out_specs=P(lane),
            check_rep=False,  # scores replicated only over trivial axes; RNG defeats inference
        ))
        self._sharded_fns[k_pad] = fn
        return fn

    def evaluate_batch(self, ks: Sequence[int]) -> list[float]:
        from repro.core.scoring import davies_bouldin_score_masked, silhouette_score_masked

        from .batching import batched_lanes

        tracer = get_tracer()
        padded, k_pad, n_real = self._pad_ks(ks)
        t0_us = tracer.now_us()
        if self.mesh is not None:
            with tracer.span("fit", track=self._dispatch_track(), kind="kmeans",
                             ks=[int(k) for k in ks], batch=len(padded), k_pad=k_pad):
                ks_arr, keys, k_pad = batched_lanes(padded, self.key, k_pad)
                scores = self._sharded_fn(k_pad)(ks_arr, keys, self.x)
            with tracer.span("score", track=self._dispatch_track(), kind=self.score,
                             batch=len(padded)):
                out = [float(s) for s in scores[:n_real]]
            self._emit_lane_spans(tracer, t0_us, padded, n_real, kind="kmeans")
            return out
        with tracer.span("fit", track=self._dispatch_track(), kind="kmeans",
                         ks=[int(k) for k in ks], batch=len(padded), k_pad=k_pad):
            res = kmeans_batched(self.x, padded, self.key, k_pad=k_pad, max_iters=self.max_iters)
        ks_arr = jnp.asarray(padded)
        cluster_mask = jnp.arange(k_pad)[None, :] < ks_arr[:, None]  # (b, k_pad)
        # x stays unbatched (n, d): the jnp scorer tiers broadcast it against
        # the batched labels so the point-pairwise work is done once, while
        # the Pallas tier streams per-lane tiles that never hit HBM.
        with tracer.span("score", track=self._dispatch_track(), kind=self.score,
                         batch=len(padded)):
            if self.score == "davies_bouldin":
                scores = davies_bouldin_score_masked(
                    self.x, res.labels, k_pad, cluster_mask=cluster_mask
                )
            else:
                scores = silhouette_score_masked(
                    self.x, res.labels, k_pad, use_kernel=self.use_kernel
                )
            return [float(s) for s in scores[:n_real]]


__all__ = ["NMFkBatchPlane", "KMeansBatchPlane"]
