"""Factorization & clustering substrates the paper selects k for."""
from .distributed import (  # noqa: F401
    distributed_nmf,
    distributed_rescal,
    make_local_mesh,
)
from .kmeans import KMeansResult, kmeans, kmeans_batched, kmeans_multi_restart  # noqa: F401
from .nmf import (  # noqa: F401
    NMFResult,
    mu_step,
    nmf,
    nmf_batched,
    nmf_chunked,
    nmf_init,
    reconstruction_error,
)
from .nmfk import (  # noqa: F401
    NMFkScore,
    make_nmfk_evaluator,
    nmfk_score,
    nmfk_score_batched,
)
from .batching import WarmStartCache  # noqa: F401
from .planes import KMeansBatchPlane, NMFkBatchPlane, NMFkElasticPlane  # noqa: F401
from .rescal import (  # noqa: F401
    RESCALResult,
    make_rescalk_evaluator,
    rescal,
    rescalk_score,
)
from .synthetic import blob_data, nmf_data, rescal_data  # noqa: F401
