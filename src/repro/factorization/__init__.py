"""Factorization & clustering substrates the paper selects k for."""
from .distributed import (  # noqa: F401
    distributed_nmf,
    distributed_rescal,
    make_local_mesh,
)
from .kmeans import KMeansResult, kmeans, kmeans_multi_restart  # noqa: F401
from .nmf import NMFResult, mu_step, nmf, nmf_chunked, reconstruction_error  # noqa: F401
from .nmfk import NMFkScore, make_nmfk_evaluator, nmfk_score  # noqa: F401
from .rescal import (  # noqa: F401
    RESCALResult,
    make_rescalk_evaluator,
    rescal,
    rescalk_score,
)
from .synthetic import blob_data, nmf_data, rescal_data  # noqa: F401
