"""Distributed NMF / RESCAL via shard_map — the paper's pyDNMFk/pyDRESCALk.

The paper's *distributed* mode: one k evaluation is too big for a node
(50 TB matrices, 52k cores), so the factorization itself is sharded. The
MPI communication structure of pyDNMFk maps 1:1 onto jax.lax collectives:

    V row-sharded over the mesh axis; W row-sharded; H replicated.
      H-update:  psum(W_l^T V_l) (k×m),  psum(W_l^T W_l) (k×k)
      W-update:  purely local (H replicated ⇒ H H^T local)

Gram-matrix psums are k×{m,k} — tiny next to V — so the algorithm is
compute-bound and scales like the paper's 52k-core runs. RESCAL adds an
all-gather of the entity factor A (n×k) per sweep.

Two communication schedules for the MU sweeps (``comm=``):

  * ``"sync"`` — each sweep blocks on the two Gram all-reduces before any
    factor update (the textbook pyDNMFk order).
  * ``"pipelined"`` — each psum is decomposed into ``psum_scatter`` + ring
    ``all_gather`` (``ring_psum``), both Grams fused into one buffer so one
    collective pair is in flight per sweep, and the purely-local W-update
    runs with a **one-sweep-stale H** while the reduction is in transit.
    The W-update has no data dependency on the in-flight Grams, so XLA's
    async-collective scheduler overlaps communication with compute; a
    final synchronous sweep restores the coupled update before the
    residual is measured. Numerics differ from ``"sync"`` by the staleness
    (rel_error agreement ~5e-2 on small problems, see the conformance
    suite); total sweep count is identical.

These functions are shard_map'd under a caller-provided mesh: a Binary
Bleed "resource" hands us its sub-mesh, giving the paper's
parallel-over-k × distributed-within-k composition.
"""
from __future__ import annotations

import functools
import inspect
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 stable API
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

COMM_MODES = ("sync", "pipelined")


def _resolve_unreplicated_kwarg(fn) -> str:
    """Which kwarg disables shard_map's replication check for ``fn``.

    jax < 0.7 spells it ``check_rep``; newer jax renamed it ``check_vma``.
    Resolved ONCE at import time from the signature — the shim used to
    re-probe via a try/except TypeError on every call, which both paid the
    probe per dispatch and masked unrelated TypeErrors from the first
    spelling.
    """
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - C-level callable
        return "check_rep"
    if "check_rep" in params:
        return "check_rep"
    if "check_vma" in params:
        return "check_vma"
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        # opaque **kwargs wrapper: assume the modern spelling
        return "check_vma"
    return "check_rep"  # pragma: no cover - neither spelling: fail loudly later


_CHECK_KWARG = _resolve_unreplicated_kwarg(_shard_map)


def shard_map(f, mesh, in_specs, out_specs, check_rep: bool = True):
    """Version shim. ``check_rep=False`` is needed where the replication of
    an output can't be statically inferred (e.g. scores derived from RNG +
    all_gather in the sharded NMFk plane) — newer jax renamed the kwarg,
    and ``_CHECK_KWARG`` holds the spelling this jax supports."""
    if check_rep:
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KWARG: False}
    )


Array = jax.Array
_EPS = 1e-9


# ---------------------------------------------------------------------------
# ring collectives: psum decomposed into scatter + gather
# ---------------------------------------------------------------------------
def ring_all_gather(x: Array, axis: str, axis_size: int, use_ppermute: bool = False) -> Array:
    """All-gather ``x`` (a per-device chunk) along ``axis``.

    ``use_ppermute=True`` spells the gather as an explicit (axis_size - 1)-step
    ``ppermute`` ring — the schedule pyDNMFk's custom communicators build by
    hand, and the form whose per-step transfers interleave with compute on
    hardware rings. The default lowers to ``lax.all_gather`` and lets XLA
    pick the ring; both produce identical values.
    """
    if axis_size == 1:
        return x
    if not use_ppermute:
        return jax.lax.all_gather(x, axis, tiled=True)
    idx = jax.lax.axis_index(axis)
    chunk = x.shape[0]
    out = jnp.zeros((axis_size * chunk,) + x.shape[1:], x.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, x, idx * chunk, axis=0)
    buf = x
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    for step in range(1, axis_size):
        buf = jax.lax.ppermute(buf, axis, perm)
        src = (idx - step) % axis_size
        out = jax.lax.dynamic_update_slice_in_dim(out, buf, src * chunk, axis=0)
    return out


def ring_psum_start(x: Array, axis: str, axis_size: int) -> tuple[Array, int]:
    """First half of a decomposed psum: reduce-scatter ``x`` over ``axis``.

    Pads the leading dim to a multiple of ``axis_size`` (Gram matrices are
    k_pad-leading; k_pad need not divide the shard count) and returns the
    per-device reduced chunk plus the original leading extent. Everything
    between ``ring_psum_start`` and ``ring_psum_finish`` has no data
    dependency on the reduction, so the scheduler can run it while the
    collective is in flight.
    """
    if axis_size == 1:
        return x, x.shape[0]
    lead = x.shape[0]
    pad = (-lead) % axis_size
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    shard = jax.lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)
    return shard, lead


def ring_psum_finish(
    shard: Array, lead: int, axis: str, axis_size: int, use_ppermute: bool = False
) -> Array:
    """Second half of a decomposed psum: gather the reduced chunks."""
    if axis_size == 1:
        return shard
    full = ring_all_gather(shard, axis, axis_size, use_ppermute=use_ppermute)
    return full[:lead] if full.shape[0] != lead else full


def ring_psum(x: Array, axis: str, axis_size: int, use_ppermute: bool = False) -> Array:
    """``lax.psum`` decomposed into ``psum_scatter`` + ring all-gather.

    Identical result up to float reduction order; the two-phase form is
    what the pipelined MU schedule interleaves compute into.
    """
    shard, lead = ring_psum_start(x, axis, axis_size)
    return ring_psum_finish(shard, lead, axis, axis_size, use_ppermute=use_ppermute)


def overlap_model(
    n_total: int,
    m: int,
    k_pad: int,
    data: int,
    machine_balance: float = 8.0,
) -> dict:
    """Analytic comm/compute model of one pipelined MU sweep per device.

    The ring moves ``2 (p-1)/p`` of the fused Gram buffer (reduce-scatter +
    all-gather) while the local stale-H W-update runs; ``machine_balance``
    converts moved elements into flop-equivalents (flops the machine
    executes in the time one element crosses the interconnect — a roofline
    balance knob, default representative of a CPU/Ethernet-class ratio;
    TPU-class fabrics are lower, hiding comm even more easily).

    Returns ``overlap_fraction`` (share of comm hidden behind the W-update),
    ``comm_fraction`` (comm share of the *sync* sweep), and the modeled
    pipelined-vs-sync ``speedup``. All quantities are per sweep; with
    ``data == 1`` there is no communication and every field degenerates to
    the no-op values.
    """
    if data <= 1:
        return {
            "overlap_fraction": 0.0,
            "comm_fraction": 0.0,
            "speedup": 1.0,
            "comm_flop_equiv": 0.0,
            "local_flops": 0.0,
        }
    n_l = n_total / data
    gram_elems = k_pad * (m + k_pad)
    comm_elems = 2.0 * (data - 1) / data * gram_elems
    comm_cost = comm_elems * machine_balance  # flop-equivalents
    # local work available to hide the in-flight ring: the W-update
    w_update_flops = 2.0 * n_l * m * k_pad + 2.0 * k_pad * k_pad * (m + n_l)
    # rest of the sweep: Gram products + H-update
    gram_flops = 2.0 * n_l * (m + k_pad) * k_pad
    h_update_flops = 2.0 * k_pad * k_pad * m
    compute = w_update_flops + gram_flops + h_update_flops
    overlap = min(w_update_flops, comm_cost) / comm_cost
    t_sync = compute + comm_cost
    t_pipe = compute + comm_cost * (1.0 - overlap)
    return {
        "overlap_fraction": overlap,
        "comm_fraction": comm_cost / t_sync,
        "speedup": t_sync / t_pipe,
        "comm_flop_equiv": comm_cost,
        "local_flops": w_update_flops,
    }


class DistNMFResult(NamedTuple):
    w: Array  # (n, k) row-sharded
    h: Array  # (k, m) replicated
    rel_error: Array


def _mu_sweeps(
    v_l: Array,
    w_l: Array,
    h: Array,
    active: Array | None,
    iters: int,
    axis: str,
    comm: str,
    axis_size: int,
    steps: Array | None = None,
):
    """Run ``iters`` multiplicative-update sweeps under the chosen schedule.

    ``active`` is the (k_pad,) rank mask of the masked fits (None for the
    unmasked path). ``"sync"`` blocks both factor updates on the Gram
    psums; ``"pipelined"`` fuses the two Grams into one ``(k, m+k)`` buffer,
    reduce-scatters it, runs the local W-update with the previous sweep's
    H while the ring gather is in flight, then finishes the H-update — a
    one-sweep-stale schedule closed by one final synchronous sweep so the
    measured residual comes from a coupled (W, H) pair.

    ``steps`` (a traced scalar) gates sweeps per call inside the fixed
    ``iters``-shaped loop: sweep s applies only while ``s < steps`` — the
    elastic executor's per-lane remaining-budget gate. With ``steps <
    iters`` under ``"pipelined"`` the closing synchronous sweep is gated
    off too (the lane's last applied sweep is a stale-H pipe sweep); the
    elastic conformance tolerance for pipelined runs absorbs this.
    """
    if comm not in COMM_MODES:
        raise ValueError(f"comm must be one of {COMM_MODES}, got {comm!r}")
    m = v_l.shape[1]

    def mask_h(h):
        return h if active is None else h * active[:, None]

    def mask_w(w):
        return w if active is None else w * active[None, :]

    def sync_sweep(carry):
        w_l, h = carry
        wtv = jax.lax.psum(w_l.T @ v_l, axis)  # (k, m) — the pyDNMFk all-reduce
        wtw = jax.lax.psum(w_l.T @ w_l, axis)  # (k, k)
        h = mask_h(h * wtv / (wtw @ h + _EPS))
        hht = h @ h.T  # local: H replicated
        w_l = mask_w(w_l * (v_l @ h.T) / (w_l @ hht + _EPS))
        return w_l, h

    def pipe_sweep(carry):
        w_l, h = carry
        # fused Gram: one scatter+gather pair in flight instead of two psums
        gram = w_l.T @ jnp.concatenate([v_l, w_l], axis=1)  # (k, m + k)
        shard, lead = ring_psum_start(gram, axis, axis_size)
        # ... overlapped: purely-local W-update with the stale (prev-sweep) H;
        # no data dependency on `shard`, so it hides the in-flight ring
        hht = h @ h.T
        w_new = mask_w(w_l * (v_l @ h.T) / (w_l @ hht + _EPS))
        # ... then complete the reduction and the H-update
        full = ring_psum_finish(shard, lead, axis, axis_size)
        wtv, wtw = full[:, :m], full[:, m:]
        h_new = mask_h(h * wtv / (wtw @ h + _EPS))
        return w_new, h_new

    def gated(s, carry, sweep):
        new = sweep(carry)
        if steps is None:
            return new
        live = s < steps
        return jnp.where(live, new[0], carry[0]), jnp.where(live, new[1], carry[1])

    if comm == "sync" or axis_size == 1 or iters == 0:
        return jax.lax.fori_loop(0, iters, lambda s, c: gated(s, c, sync_sweep), (w_l, h))
    w_l, h = jax.lax.fori_loop(0, iters - 1, lambda s, c: gated(s, c, pipe_sweep), (w_l, h))
    return gated(iters - 1, (w_l, h), sync_sweep)


def _dnmf_local(
    v_l: Array,
    key: Array,
    k: int,
    iters: int,
    axis: str,
    comm: str = "sync",
    axis_size: int = 1,
):
    """Per-shard NMF body. v_l: (n_local, m)."""
    n_l, m = v_l.shape
    idx = jax.lax.axis_index(axis)
    kw, kh = jax.random.split(key)
    # H must be bit-identical on every shard: same key everywhere.
    # W is local: fold in the shard index.
    v_mean = jax.lax.pmean(jnp.mean(v_l), axis)
    scale = jnp.sqrt(jnp.maximum(v_mean, _EPS) / k)
    w_l = scale * jax.random.uniform(jax.random.fold_in(kw, idx), (n_l, k), v_l.dtype, 0.1, 1.0)
    h = scale * jax.random.uniform(kh, (k, m), v_l.dtype, 0.1, 1.0)

    w_l, h = _mu_sweeps(v_l, w_l, h, None, iters, axis, comm, axis_size)
    sq = jnp.sum((v_l - w_l @ h) ** 2)
    vsq = jnp.sum(v_l**2)
    err = jnp.sqrt(jax.lax.psum(sq, axis) / jnp.maximum(jax.lax.psum(vsq, axis), _EPS))
    return w_l, h, err


def distributed_nmf(
    v: Array,
    k: int,
    key: Array,
    mesh: Mesh,
    iters: int = 200,
    axis: str = "data",
    comm: str = "sync",
) -> DistNMFResult:
    """Row-distributed NMF under `mesh` (v rows sharded over `axis`).

    ``comm="pipelined"`` overlaps the Gram reductions with the local
    W-update (one-sweep-stale H; see the module docstring).
    """
    axis_size = dict(mesh.shape)[axis]
    fn = shard_map(
        functools.partial(
            _dnmf_local, k=k, iters=iters, axis=axis, comm=comm, axis_size=axis_size
        ),
        mesh,
        in_specs=(P(axis, None), P()),
        out_specs=(P(axis, None), P(), P()),
        # the ring gather's replication is invisible to rep inference
        check_rep=(comm == "sync" or axis_size == 1),
    )
    v = jax.device_put(v, NamedSharding(mesh, P(axis, None)))
    w, h, err = jax.jit(fn)(v, key)
    return DistNMFResult(w, h, err)


class DistRESCALResult(NamedTuple):
    a: Array  # (n, k) row-sharded
    r: Array  # (nr, k, k) replicated
    rel_error: Array


def _drescal_local(x_l: Array, key: Array, k: int, iters: int, axis: str):
    """Per-shard RESCAL body. x_l: (nr, n_local, n) — entity-row sharded."""
    nr, n_l, n = x_l.shape
    idx = jax.lax.axis_index(axis)
    ka, kr = jax.random.split(key)
    x_mean = jax.lax.pmean(jnp.mean(x_l), axis)
    scale = jnp.sqrt(jnp.maximum(x_mean, _EPS)) / k
    a_l = scale * jax.random.uniform(jax.random.fold_in(ka, idx), (n_l, k), x_l.dtype, 0.1, 1.0)
    r = scale * jax.random.uniform(kr, (nr, k, k), x_l.dtype, 0.1, 1.0)

    def body(_, carry):
        a_l, r = carry
        a_full = jax.lax.all_gather(a_l, axis, tiled=True)  # (n, k)
        ata = jax.lax.psum(a_l.T @ a_l, axis)  # (k, k)
        # A-update numerator, local rows:
        #   X_r A R_r^T  +  X_r^T A R_r   (row slice of the second term
        #   reconstructed from the local row block via psum)
        xar = jnp.einsum("rij,jl,rkl->ik", x_l, a_full, r)  # (n_l, k)
        xt_a_full = jax.lax.psum(
            jnp.einsum("rij,il->rjl", x_l, a_l), axis
        )  # (nr, n, k) = X_r^T A
        start = idx * n_l
        xt_a_l = jax.lax.dynamic_slice_in_dim(xt_a_full, start, n_l, axis=1)  # (nr, n_l, k)
        xar2 = jnp.einsum("rik,rkl->il", xt_a_l, r)  # X_r^T A R_r rows
        num = xar + xar2
        arat = jnp.einsum("rkl,lm,rnm->kn", r, ata, r)
        arat2 = jnp.einsum("rlk,lm,rmn->kn", r, ata, r)
        den = a_l @ (arat + arat2)
        a_l = a_l * num / (den + _EPS)
        # R-update
        ata = jax.lax.psum(a_l.T @ a_l, axis)
        a_full = jax.lax.all_gather(a_l, axis, tiled=True)
        atxa = jax.lax.psum(
            jnp.einsum("il,rij,jm->rlm", a_l, x_l, a_full), axis
        )  # (nr, k, k)
        den_r = jnp.einsum("ik,rkl,lj->rij", ata, r, ata)
        r = r * atxa / (den_r + _EPS)
        return a_l, r

    a_l, r = jax.lax.fori_loop(0, iters, body, (a_l, r))
    a_full = jax.lax.all_gather(a_l, axis, tiled=True)
    recon_l = jnp.einsum("ik,rkl,jl->rij", a_l, r, a_full)
    sq = jnp.sum((x_l - recon_l) ** 2)
    xsq = jnp.sum(x_l**2)
    err = jnp.sqrt(jax.lax.psum(sq, axis) / jnp.maximum(jax.lax.psum(xsq, axis), _EPS))
    return a_l, r, err


def distributed_rescal(
    x: Array,
    k: int,
    key: Array,
    mesh: Mesh,
    iters: int = 150,
    axis: str = "data",
) -> DistRESCALResult:
    """Entity-row-distributed RESCAL under `mesh`."""
    fn = shard_map(
        functools.partial(_drescal_local, k=k, iters=iters, axis=axis),
        mesh,
        in_specs=(P(None, axis, None), P()),
        out_specs=(P(axis, None), P(), P()),
    )
    x = jax.device_put(x, NamedSharding(mesh, P(None, axis, None)))
    a, r, err = jax.jit(fn)(x, key)
    return DistRESCALResult(a, r, err)


def _dnmf_masked_local(
    v_l: Array,
    k_eff: Array,
    key: Array,
    k_pad: int,
    iters: int,
    axis: str,
    n_total: int,
    comm: str = "sync",
) -> tuple[Array, Array]:
    """Per-shard *masked* NMF body: ``_nmf_masked`` distributed over ``axis``.

    Same psum structure as ``_dnmf_local`` (H-update Gram matrices are the
    only collectives), but draw-compatible with the single-device masked
    fit: W and H are drawn full-shape from the replicated ``key`` exactly as
    ``_nmf_masked`` draws them, and each shard keeps only its row block of
    W. All cross-shard reductions are psums of k_pad×{m,k_pad} Grams, so
    with ``comm="sync"`` the result matches ``_nmf_masked(v, k_eff, key,
    k_pad, iters)`` up to float reduction order; ``comm="pipelined"``
    additionally carries the one-sweep-stale W-update schedule (see module
    docstring), trading exact sync parity for comm/compute overlap.

    v_l: (n_local, m) local row block. Returns (w_l, rel_error) with
    rel_error the *global* ||V - WH||_F / ||V||_F.
    """
    n_l, m = v_l.shape
    axis_size = n_total // n_l  # shapes are static under shard_map/vmap
    idx = jax.lax.axis_index(axis)
    active = jnp.arange(k_pad) < k_eff
    kw, kh = jax.random.split(key)
    v_mean = jax.lax.psum(jnp.sum(v_l), axis) / (n_total * m)
    scale = jnp.sqrt(jnp.maximum(v_mean, _EPS) / k_eff)
    # replicated full-shape draw, then slice this shard's rows — bit-compatible
    # with the single-device init (the Gram psums below are where fp order
    # can differ, not the init)
    w_full = scale * jax.random.uniform(kw, (n_total, k_pad), v_l.dtype, 0.1, 1.0)
    w_l = jax.lax.dynamic_slice_in_dim(w_full, idx * n_l, n_l, axis=0)
    h = scale * jax.random.uniform(kh, (k_pad, m), v_l.dtype, 0.1, 1.0)
    w_l = w_l * active[None, :]
    h = h * active[:, None]

    w_l, h = _mu_sweeps(v_l, w_l, h, active, iters, axis, comm, axis_size)
    sq = jax.lax.psum(jnp.sum((v_l - w_l @ h) ** 2), axis)
    vsq = jax.lax.psum(jnp.sum(v_l**2), axis)
    err = jnp.sqrt(sq) / jnp.maximum(jnp.sqrt(vsq), _EPS)
    return w_l, err


def _dnmf_masked_chunk_local(
    v_l: Array,
    w_l: Array,
    h: Array,
    k_eff: Array,
    k_pad: int,
    chunk: int,
    axis: str,
    axis_size: int,
    comm: str = "sync",
    steps: Array | None = None,
) -> tuple[Array, Array, Array]:
    """Resumable chunk of a masked data-sharded fit: ``chunk`` MU sweeps
    (per-lane gated to ``steps`` when given) plus the *global* rel_error
    from the existing psum structure.

    The elastic executor's convergence gate under data sharding: the
    residual ``||V - WH||_F / ||V||_F`` is assembled from per-shard squared
    sums with the same two psums the Gram updates already pay, so testing
    convergence at a chunk boundary costs one extra scalar all-reduce pair
    — no gather of V or W. ``comm="pipelined"`` runs the one-sweep-stale
    overlapped schedule *within* the chunk (each chunk closes with one
    synchronous sweep, exactly like a short ``_mu_sweeps`` run).

    v_l: (n_local, m) row block; w_l: (n_local, k_pad) local rows; h
    replicated. Returns (w_l, h, rel_error) with rel_error replicated.
    """
    active = jnp.arange(k_pad) < k_eff
    w_l, h = _mu_sweeps(v_l, w_l, h, active, chunk, axis, comm, axis_size, steps=steps)
    sq = jax.lax.psum(jnp.sum((v_l - w_l @ h) ** 2), axis)
    vsq = jax.lax.psum(jnp.sum(v_l**2), axis)
    err = jnp.sqrt(sq) / jnp.maximum(jnp.sqrt(vsq), _EPS)
    return w_l, h, err


def make_local_mesh(n_devices: int | None = None, axis: str = "data") -> Mesh:
    """1-D mesh over available devices (tests run this with 1 CPU device)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    return jax.make_mesh((n,), (axis,), devices=devs[:n])
