"""Distributed NMF / RESCAL via shard_map — the paper's pyDNMFk/pyDRESCALk.

The paper's *distributed* mode: one k evaluation is too big for a node
(50 TB matrices, 52k cores), so the factorization itself is sharded. The
MPI communication structure of pyDNMFk maps 1:1 onto jax.lax collectives:

    V row-sharded over the mesh axis; W row-sharded; H replicated.
      H-update:  psum(W_l^T V_l) (k×m),  psum(W_l^T W_l) (k×k)
      W-update:  purely local (H replicated ⇒ H H^T local)

Gram-matrix psums are k×{m,k} — tiny next to V — so the algorithm is
compute-bound and scales like the paper's 52k-core runs. RESCAL adds an
all-gather of the entity factor A (n×k) per sweep.

These functions are shard_map'd under a caller-provided mesh: a Binary
Bleed "resource" hands us its sub-mesh, giving the paper's
parallel-over-k × distributed-within-k composition.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 stable API
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, mesh, in_specs, out_specs, check_rep: bool = True):
    """Version shim. ``check_rep=False`` is needed where the replication of
    an output can't be statically inferred (e.g. scores derived from RNG +
    all_gather in the sharded NMFk plane) — newer jax renamed the kwarg."""
    if check_rep:
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )
    except TypeError:  # pragma: no cover - jax >= 0.7 renamed to check_vma
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )


Array = jax.Array
_EPS = 1e-9


class DistNMFResult(NamedTuple):
    w: Array  # (n, k) row-sharded
    h: Array  # (k, m) replicated
    rel_error: Array


def _dnmf_local(v_l: Array, key: Array, k: int, iters: int, axis: str):
    """Per-shard NMF body. v_l: (n_local, m)."""
    n_l, m = v_l.shape
    idx = jax.lax.axis_index(axis)
    kw, kh = jax.random.split(key)
    # H must be bit-identical on every shard: same key everywhere.
    # W is local: fold in the shard index.
    v_mean = jax.lax.pmean(jnp.mean(v_l), axis)
    scale = jnp.sqrt(jnp.maximum(v_mean, _EPS) / k)
    w_l = scale * jax.random.uniform(jax.random.fold_in(kw, idx), (n_l, k), v_l.dtype, 0.1, 1.0)
    h = scale * jax.random.uniform(kh, (k, m), v_l.dtype, 0.1, 1.0)

    def body(_, carry):
        w_l, h = carry
        wtv = jax.lax.psum(w_l.T @ v_l, axis)  # (k, m) — the pyDNMFk all-reduce
        wtw = jax.lax.psum(w_l.T @ w_l, axis)  # (k, k)
        h = h * wtv / (wtw @ h + _EPS)
        hht = h @ h.T  # local: H replicated
        w_l = w_l * (v_l @ h.T) / (w_l @ hht + _EPS)
        return w_l, h

    w_l, h = jax.lax.fori_loop(0, iters, body, (w_l, h))
    sq = jnp.sum((v_l - w_l @ h) ** 2)
    vsq = jnp.sum(v_l**2)
    err = jnp.sqrt(jax.lax.psum(sq, axis) / jnp.maximum(jax.lax.psum(vsq, axis), _EPS))
    return w_l, h, err


def distributed_nmf(
    v: Array,
    k: int,
    key: Array,
    mesh: Mesh,
    iters: int = 200,
    axis: str = "data",
) -> DistNMFResult:
    """Row-distributed NMF under `mesh` (v rows sharded over `axis`)."""
    fn = shard_map(
        functools.partial(_dnmf_local, k=k, iters=iters, axis=axis),
        mesh,
        in_specs=(P(axis, None), P()),
        out_specs=(P(axis, None), P(), P()),
    )
    v = jax.device_put(v, NamedSharding(mesh, P(axis, None)))
    w, h, err = jax.jit(fn)(v, key)
    return DistNMFResult(w, h, err)


class DistRESCALResult(NamedTuple):
    a: Array  # (n, k) row-sharded
    r: Array  # (nr, k, k) replicated
    rel_error: Array


def _drescal_local(x_l: Array, key: Array, k: int, iters: int, axis: str):
    """Per-shard RESCAL body. x_l: (nr, n_local, n) — entity-row sharded."""
    nr, n_l, n = x_l.shape
    idx = jax.lax.axis_index(axis)
    ka, kr = jax.random.split(key)
    x_mean = jax.lax.pmean(jnp.mean(x_l), axis)
    scale = jnp.sqrt(jnp.maximum(x_mean, _EPS)) / k
    a_l = scale * jax.random.uniform(jax.random.fold_in(ka, idx), (n_l, k), x_l.dtype, 0.1, 1.0)
    r = scale * jax.random.uniform(kr, (nr, k, k), x_l.dtype, 0.1, 1.0)

    def body(_, carry):
        a_l, r = carry
        a_full = jax.lax.all_gather(a_l, axis, tiled=True)  # (n, k)
        ata = jax.lax.psum(a_l.T @ a_l, axis)  # (k, k)
        # A-update numerator, local rows:
        #   X_r A R_r^T  +  X_r^T A R_r   (row slice of the second term
        #   reconstructed from the local row block via psum)
        xar = jnp.einsum("rij,jl,rkl->ik", x_l, a_full, r)  # (n_l, k)
        xt_a_full = jax.lax.psum(
            jnp.einsum("rij,il->rjl", x_l, a_l), axis
        )  # (nr, n, k) = X_r^T A
        start = idx * n_l
        xt_a_l = jax.lax.dynamic_slice_in_dim(xt_a_full, start, n_l, axis=1)  # (nr, n_l, k)
        xar2 = jnp.einsum("rik,rkl->il", xt_a_l, r)  # X_r^T A R_r rows
        num = xar + xar2
        arat = jnp.einsum("rkl,lm,rnm->kn", r, ata, r)
        arat2 = jnp.einsum("rlk,lm,rmn->kn", r, ata, r)
        den = a_l @ (arat + arat2)
        a_l = a_l * num / (den + _EPS)
        # R-update
        ata = jax.lax.psum(a_l.T @ a_l, axis)
        a_full = jax.lax.all_gather(a_l, axis, tiled=True)
        atxa = jax.lax.psum(
            jnp.einsum("il,rij,jm->rlm", a_l, x_l, a_full), axis
        )  # (nr, k, k)
        den_r = jnp.einsum("ik,rkl,lj->rij", ata, r, ata)
        r = r * atxa / (den_r + _EPS)
        return a_l, r

    a_l, r = jax.lax.fori_loop(0, iters, body, (a_l, r))
    a_full = jax.lax.all_gather(a_l, axis, tiled=True)
    recon_l = jnp.einsum("ik,rkl,jl->rij", a_l, r, a_full)
    sq = jnp.sum((x_l - recon_l) ** 2)
    xsq = jnp.sum(x_l**2)
    err = jnp.sqrt(jax.lax.psum(sq, axis) / jnp.maximum(jax.lax.psum(xsq, axis), _EPS))
    return a_l, r, err


def distributed_rescal(
    x: Array,
    k: int,
    key: Array,
    mesh: Mesh,
    iters: int = 150,
    axis: str = "data",
) -> DistRESCALResult:
    """Entity-row-distributed RESCAL under `mesh`."""
    fn = shard_map(
        functools.partial(_drescal_local, k=k, iters=iters, axis=axis),
        mesh,
        in_specs=(P(None, axis, None), P()),
        out_specs=(P(axis, None), P(), P()),
    )
    x = jax.device_put(x, NamedSharding(mesh, P(None, axis, None)))
    a, r, err = jax.jit(fn)(x, key)
    return DistRESCALResult(a, r, err)


def _dnmf_masked_local(
    v_l: Array,
    k_eff: Array,
    key: Array,
    k_pad: int,
    iters: int,
    axis: str,
    n_total: int,
) -> tuple[Array, Array]:
    """Per-shard *masked* NMF body: ``_nmf_masked`` distributed over ``axis``.

    Same psum structure as ``_dnmf_local`` (H-update Gram matrices are the
    only collectives), but draw-compatible with the single-device masked
    fit: W and H are drawn full-shape from the replicated ``key`` exactly as
    ``_nmf_masked`` draws them, and each shard keeps only its row block of
    W. All cross-shard reductions are psums of k_pad×{m,k_pad} Grams, so
    the result matches ``_nmf_masked(v, k_eff, key, k_pad, iters)`` up to
    float reduction order.

    v_l: (n_local, m) local row block. Returns (w_l, rel_error) with
    rel_error the *global* ||V - WH||_F / ||V||_F.
    """
    n_l, m = v_l.shape
    idx = jax.lax.axis_index(axis)
    active = jnp.arange(k_pad) < k_eff
    kw, kh = jax.random.split(key)
    v_mean = jax.lax.psum(jnp.sum(v_l), axis) / (n_total * m)
    scale = jnp.sqrt(jnp.maximum(v_mean, _EPS) / k_eff)
    # replicated full-shape draw, then slice this shard's rows — bit-compatible
    # with the single-device init (the Gram psums below are where fp order
    # can differ, not the init)
    w_full = scale * jax.random.uniform(kw, (n_total, k_pad), v_l.dtype, 0.1, 1.0)
    w_l = jax.lax.dynamic_slice_in_dim(w_full, idx * n_l, n_l, axis=0)
    h = scale * jax.random.uniform(kh, (k_pad, m), v_l.dtype, 0.1, 1.0)
    w_l = w_l * active[None, :]
    h = h * active[:, None]

    def body(_, carry):
        w_l, h = carry
        wtv = jax.lax.psum(w_l.T @ v_l, axis)  # (k_pad, m)
        wtw = jax.lax.psum(w_l.T @ w_l, axis)  # (k_pad, k_pad)
        h = h * wtv / (wtw @ h + _EPS)
        h = h * active[:, None]
        hht = h @ h.T  # local: H replicated
        w_l = w_l * (v_l @ h.T) / (w_l @ hht + _EPS)
        w_l = w_l * active[None, :]
        return w_l, h

    w_l, h = jax.lax.fori_loop(0, iters, body, (w_l, h))
    sq = jax.lax.psum(jnp.sum((v_l - w_l @ h) ** 2), axis)
    vsq = jax.lax.psum(jnp.sum(v_l**2), axis)
    err = jnp.sqrt(sq) / jnp.maximum(jnp.sqrt(vsq), _EPS)
    return w_l, err


def make_local_mesh(n_devices: int | None = None, axis: str = "data") -> Mesh:
    """1-D mesh over available devices (tests run this with 1 CPU device)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    return jax.make_mesh((n,), (axis,), devices=devs[:n])
