"""Nonnegative Matrix Factorization via multiplicative updates (Frobenius).

The paper's T_model: V (n, m) ≈ W (n, k) H (k, m), W,H >= 0, with the
classic Lee-Seung updates

    H <- H * (W^T V) / (W^T W H + eps)
    W <- W * (V H^T) / (W H H^T + eps)

Two execution paths:
  * ``nmf`` — fully jit'd ``lax.fori_loop`` (fast path for benchmarks).
  * ``nmf_chunked`` — Python loop over jit'd iteration chunks with a
    ``should_abort`` poll between chunks: the paper's §III-D "checks can be
    pushed into the model to terminate such k early" — when another Binary
    Bleed resource prunes this k mid-fit, we stop paying for it. TPU steps
    are not preemptible, so bounded-staleness chunk-granular aborts are the
    TPU-native adaptation.

``use_kernel=True`` routes the H/W updates through the fused Pallas MU
kernel (repro.kernels.nmf_update) — the compute hot spot the paper's
distributed NMF optimizes on GPU, re-tiled for TPU VMEM/MXU.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .batching import batched_lanes

Array = jax.Array
_EPS = 1e-9


class NMFResult(NamedTuple):
    w: Array
    h: Array
    rel_error: Array  # ||V - WH||_F / ||V||_F
    iters: Array


def nmf_init(
    key: Array, n: int, m: int, k: int, v_mean: Array, dtype, k_pad: int | None = None
) -> tuple[Array, Array]:
    """Scaled-uniform W/H init.

    With ``k_pad`` the draw happens at the padded rank and is sliced to k —
    exactly the active block a mask-padded batched fit (``nmf_batched``)
    initializes from for the same key, which is what makes per-k and
    batched fits comparable factor-for-factor.
    """
    kw, kh = jax.random.split(key)
    scale = jnp.sqrt(jnp.maximum(v_mean, _EPS) / k)
    kd = k if k_pad is None else k_pad
    w = scale * jax.random.uniform(kw, (n, kd), dtype, 0.1, 1.0)[:, :k]
    h = scale * jax.random.uniform(kh, (kd, m), dtype, 0.1, 1.0)[:k, :]
    return w, h


_init_wh = nmf_init


def mu_step(v: Array, w: Array, h: Array, use_kernel: bool = False) -> tuple[Array, Array]:
    """One multiplicative-update sweep (H then W)."""
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        h = kernel_ops.mu_update_h(v, w, h)
        w = kernel_ops.mu_update_w(v, w, h)
        return w, h
    wt = w.T
    h = h * (wt @ v) / (wt @ w @ h + _EPS)
    ht = h.T
    w = w * (v @ ht) / (w @ (h @ ht) + _EPS)
    return w, h


@functools.partial(jax.jit, static_argnames=("k", "iters", "use_kernel"))
def nmf(
    v: Array,
    k: int,
    key: Array,
    iters: int = 200,
    use_kernel: bool = False,
    w0: Array | None = None,
    h0: Array | None = None,
) -> NMFResult:
    """Jit'd NMF: fixed iteration count (TPU-friendly, no host sync).

    ``w0``/``h0`` override the random init (both or neither) — used to seed
    a per-k fit with the exact active block of a padded batched init.
    """
    n, m = v.shape
    if (w0 is None) != (h0 is None):
        raise ValueError("pass both w0 and h0, or neither")
    if w0 is None:
        w, h = nmf_init(key, n, m, k, jnp.mean(v), v.dtype)
    else:
        w, h = w0, h0

    def body(_, wh):
        return mu_step(v, *wh, use_kernel=use_kernel)

    w, h = jax.lax.fori_loop(0, iters, body, (w, h))
    err = jnp.linalg.norm(v - w @ h) / jnp.maximum(jnp.linalg.norm(v), _EPS)
    return NMFResult(w, h, err, jnp.asarray(iters))


def _masked_init(v: Array, k_eff: Array, key: Array, k_pad: int) -> tuple[Array, Array]:
    """Masked W/H init at padded rank — the exact draws ``_nmf_masked`` makes.

    Extracted so chunked/elastic fits can start from the same state a
    fixed-iteration masked fit starts from (draw-for-draw).
    """
    n, m = v.shape
    active = jnp.arange(k_pad) < k_eff
    kw, kh = jax.random.split(key)
    scale = jnp.sqrt(jnp.maximum(jnp.mean(v), _EPS) / k_eff)
    w = scale * jax.random.uniform(kw, (n, k_pad), v.dtype, 0.1, 1.0)
    h = scale * jax.random.uniform(kh, (k_pad, m), v.dtype, 0.1, 1.0)
    return w * active[None, :], h * active[:, None]


def _masked_sweeps(
    v: Array,
    w: Array,
    h: Array,
    k_eff: Array,
    k_pad: int,
    sweeps: int,
    use_kernel: bool = False,
    steps: Array | None = None,
) -> tuple[Array, Array, Array]:
    """``sweeps`` masked MU sweeps from (w, h); returns (w, h, rel_error).

    The resumable body shared by ``_nmf_masked`` and the elastic chunked
    executors: running it s1 then s2 sweeps applies the same op sequence as
    one (s1 + s2)-sweep fit, so chunk boundaries are numerically invisible.
    The returned rel_error against ``v`` is the per-chunk convergence signal
    the elastic plane's tol gate consumes.

    ``steps`` (a traced scalar) gates the loop per *call* inside a fixed
    compiled shape: sweep s applies only while ``s < steps``, so a lane
    whose remaining budget is smaller than the chunk advances exactly
    ``steps`` sweeps — bit-identical to a ``steps``-sweep fit — without
    minting a new (chunk-size) compilation.
    """
    active = jnp.arange(k_pad) < k_eff

    def body(s, wh):
        w, h = mu_step(v, *wh, use_kernel=use_kernel)
        w, h = w * active[None, :], h * active[:, None]
        if steps is None:
            return w, h
        live = s < steps
        return jnp.where(live, w, wh[0]), jnp.where(live, h, wh[1])

    w, h = jax.lax.fori_loop(0, sweeps, body, (w, h))
    err = jnp.linalg.norm(v - w @ h) / jnp.maximum(jnp.linalg.norm(v), _EPS)
    return w, h, err


@functools.partial(jax.jit, static_argnames=("k_pad", "chunk", "use_kernel"))
def _nmf_masked_chunk(
    v: Array, w: Array, h: Array, k_eff: Array, k_pad: int, chunk: int, use_kernel: bool = False
) -> tuple[Array, Array, Array]:
    """Jit'd resumable chunk of a masked fit (the elastic unit of work)."""
    return _masked_sweeps(v, w, h, k_eff, k_pad, chunk, use_kernel=use_kernel)


@functools.partial(jax.jit, static_argnames=("k_pad", "iters", "use_kernel"))
def _nmf_masked(
    v: Array,
    k_eff: Array,
    key: Array,
    k_pad: int,
    iters: int = 200,
    use_kernel: bool = False,
) -> NMFResult:
    """NMF at padded rank k_pad with components >= k_eff zero-masked.

    Lee-Seung updates preserve zeros (H rows / W columns multiply by
    themselves), so masking the init is enough for exactness; we still
    re-mask each sweep to stop eps-sized drift from re-seeding dead
    components over hundreds of iterations.
    """
    w, h = _masked_init(v, k_eff, key, k_pad)
    w, h, err = _masked_sweeps(v, w, h, k_eff, k_pad, iters, use_kernel=use_kernel)
    return NMFResult(w, h, err, jnp.asarray(iters))


def nmf_batched(
    v: Array,
    ks: Sequence[int],
    key: Array,
    k_pad: int | None = None,
    iters: int = 200,
    use_kernel: bool = False,
) -> NMFResult:
    """Fit every rank in ``ks`` as one padded vmapped NMF.

    Returns an NMFResult with a leading batch axis aligned with ``ks``:
    w (b, n, k_pad) / h (b, k_pad, m) with components >= ks[i] zeroed. One
    jit compilation at (k_pad, len(ks)) serves every rank in the wave. Lane
    i reproduces ``nmf(v, ks[i], sub, w0=w0, h0=h0)`` for
    ``sub = fold_in(key, ks[i])`` and ``w0, h0 = nmf_init(sub, n, m, ks[i],
    v.mean(), v.dtype, k_pad=k_pad)``.
    """
    ks_arr, keys, k_pad = batched_lanes(ks, key, k_pad)
    return jax.vmap(
        lambda k_eff, sub: _nmf_masked(v, k_eff, sub, k_pad, iters, use_kernel)
    )(ks_arr, keys)


@functools.partial(jax.jit, static_argnames=("k", "chunk", "use_kernel"))
def _nmf_chunk(v: Array, w: Array, h: Array, k: int, chunk: int, use_kernel: bool) -> tuple[Array, Array]:
    def body(_, wh):
        return mu_step(v, *wh, use_kernel=use_kernel)

    return jax.lax.fori_loop(0, chunk, body, (w, h))


def nmf_chunked(
    v: Array,
    k: int,
    key: Array,
    iters: int = 200,
    chunk: int = 25,
    should_abort: Callable[[], bool] | None = None,
    tol: float | None = None,
    use_kernel: bool = False,
) -> NMFResult:
    """Chunked NMF with §III-D early abort + optional convergence tol.

    Returns partial factors if aborted (callers treat the fit as void).
    """
    n, m = v.shape
    w, h = _init_wh(key, n, m, k, jnp.mean(v), v.dtype)
    v_norm = jnp.linalg.norm(v)
    done = 0
    prev_err = jnp.inf
    while done < iters:
        if should_abort is not None and should_abort():
            break
        step = min(chunk, iters - done)
        w, h = _nmf_chunk(v, w, h, k, step, use_kernel)
        done += step
        if tol is not None:
            err = float(jnp.linalg.norm(v - w @ h) / jnp.maximum(v_norm, _EPS))
            if prev_err - err < tol:
                break
            prev_err = err
    err = jnp.linalg.norm(v - w @ h) / jnp.maximum(v_norm, _EPS)
    return NMFResult(w, h, err, jnp.asarray(done))


def reconstruction_error(v: Array, w: Array, h: Array) -> Array:
    return jnp.linalg.norm(v - w @ h) / jnp.maximum(jnp.linalg.norm(v), _EPS)
