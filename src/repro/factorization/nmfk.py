"""NMFk — automatic model determination for NMF (refs [1]-[3] of the paper).

The scorer Binary Bleed wraps for NMF. For a candidate k:

  1. Create ``n_perturbs`` resampled copies of V (multiplicative uniform
     noise — bootstrap perturbations).
  2. Factorize each: W^(p), H^(p)  (vmapped over perturbations).
  3. Pool all W columns (n_perturbs × k vectors in R^n, L2-normalized) and
     custom-cluster them into k groups by greedy alignment to the medoid
     perturbation (each group holds exactly one column per perturbation —
     the LANL "custom clustering").
  4. Score: silhouette of the pooled columns under those clusters
     (cosine-like geometry via normalized vectors). Stable k ⇒ tight
     ensemble clusters ⇒ silhouette ≈ 1; overfit k ⇒ split/unstable
     components ⇒ silhouette collapses. This is the square-wave signal
     Binary Bleed's pruning assumes.

Returned score is ``min`` cluster silhouette (standard in NMFk: the weakest
component gates stability), along with mean silhouette and relative error.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.scoring import silhouette_samples_masked

from .batching import batched_lanes
from .nmf import _nmf_masked, nmf

Array = jax.Array


class NMFkScore(NamedTuple):
    min_silhouette: Array
    mean_silhouette: Array
    rel_error: Array


def _perturb(key: Array, v: Array, epsilon: float) -> Array:
    """Multiplicative uniform resampling: V ∘ U[1-eps, 1+eps]."""
    return v * jax.random.uniform(key, v.shape, v.dtype, 1.0 - epsilon, 1.0 + epsilon)


def _align_columns(w_all: Array) -> Array:
    """Greedy-match each perturbation's columns to perturbation 0's.

    w_all: (p, n, k) L2-normalized columns. Returns labels (p*k,) grouping
    each pooled column with its matched reference component — a constrained
    clustering where every cluster gets exactly one column per perturbation.
    Greedy argmax over the similarity matrix, masking used columns, is the
    jit-compatible stand-in for Hungarian matching (exact when components
    are well separated, which is the regime the silhouette then measures).
    """
    p, n, k = w_all.shape
    ref = w_all[0]  # (n, k)

    def match_one(w_p):
        sim = ref.T @ w_p  # (k_ref, k_cols)

        def body(_, carry):
            assign, sim_m = carry
            flat = jnp.argmax(sim_m)
            i, j = flat // k, flat % k
            assign = assign.at[j].set(i)
            sim_m = sim_m.at[i, :].set(-jnp.inf).at[:, j].set(-jnp.inf)
            return assign, sim_m

        assign0 = jnp.zeros((k,), jnp.int32)
        assign, _ = jax.lax.fori_loop(0, k, body, (assign0, sim))
        return assign  # column j of w_p belongs to cluster assign[j]

    assigns = jax.vmap(match_one)(w_all)  # (p, k)
    return assigns.reshape(p * k)


@functools.partial(jax.jit, static_argnames=("k", "n_perturbs", "nmf_iters", "use_kernel"))
def nmfk_score(
    v: Array,
    k: int,
    key: Array,
    n_perturbs: int = 8,
    nmf_iters: int = 150,
    epsilon: float = 0.015,
    use_kernel: bool = False,
) -> NMFkScore:
    """Silhouette-stability score of rank k (higher = stable = good)."""
    kp, kf = jax.random.split(key)
    pkeys = jax.random.split(kp, n_perturbs)
    fkeys = jax.random.split(kf, n_perturbs)

    def fit_one(pk, fk):
        vp = _perturb(pk, v, epsilon)
        res = nmf(vp, k, fk, iters=nmf_iters)
        return res.w, res.rel_error

    w_all, errs = jax.vmap(fit_one)(pkeys, fkeys)  # (p, n, k), (p,)
    # L2-normalize columns — NMFk clusters directions, not magnitudes
    w_all = w_all / jnp.maximum(jnp.linalg.norm(w_all, axis=1, keepdims=True), 1e-12)
    labels = _align_columns(w_all)  # (p*k,)
    cols = jnp.transpose(w_all, (0, 2, 1)).reshape(-1, v.shape[0])  # (p*k, n)
    # one streamed dist-sums pass yields both statistics (the pooled-column
    # distance matrix is never materialized on the blocked/Pallas tiers)
    s = silhouette_samples_masked(cols, labels, num_clusters=k, use_kernel=use_kernel)
    sil_mean = jnp.mean(s)
    onehot = jax.nn.one_hot(labels, k, dtype=cols.dtype)
    sizes = jnp.sum(onehot, axis=0)
    per_cluster = (onehot.T @ s) / jnp.maximum(sizes, 1.0)
    # guard: k=1 has a single cluster, silhouette undefined -> 1.0 (stable)
    min_sil = jnp.where(k > 1, jnp.min(per_cluster), 1.0)
    sil_mean = jnp.where(k > 1, sil_mean, 1.0)
    return NMFkScore(min_sil, sil_mean, jnp.mean(errs))


def _align_columns_masked(w_all: Array, k_eff: Array) -> Array:
    """``_align_columns`` at padded width: only the first k_eff columns of
    each perturbation participate; padded columns keep their own index as a
    throwaway label (their points are masked out of the scorer)."""
    p, n, k_pad = w_all.shape
    ref = w_all[0]
    valid = jnp.arange(k_pad) < k_eff  # (k_pad,)

    def match_one(w_p):
        sim = ref.T @ w_p  # (k_ref, k_cols)
        sim = jnp.where(valid[:, None] & valid[None, :], sim, -jnp.inf)

        def body(t, carry):
            assign, sim_m = carry
            flat = jnp.argmax(sim_m)
            i, j = flat // k_pad, flat % k_pad
            ok = t < k_eff
            assign = jnp.where(ok, assign.at[j].set(i.astype(jnp.int32)), assign)
            sim_m = jnp.where(ok, sim_m.at[i, :].set(-jnp.inf).at[:, j].set(-jnp.inf), sim_m)
            return assign, sim_m

        assign0 = jnp.arange(k_pad, dtype=jnp.int32)  # padded cols -> own slot
        assign, _ = jax.lax.fori_loop(0, k_pad, body, (assign0, sim))
        return assign

    assigns = jax.vmap(match_one)(w_all)  # (p, k_pad)
    return assigns.reshape(p * k_pad)


def _pooled_w_score(
    w_all: Array,
    errs: Array,
    k_eff: Array,
    k_pad: int,
    n_perturbs: int,
    use_kernel: bool,
) -> NMFkScore:
    """Score a fitted perturbation ensemble: the shared tail of the masked
    scorers. w_all: (p, n, k_pad) raw W factors, errs: (p,) rel errors."""
    active = jnp.arange(k_pad) < k_eff
    w_all = w_all / jnp.maximum(jnp.linalg.norm(w_all, axis=1, keepdims=True), 1e-12)
    labels = _align_columns_masked(w_all, k_eff)  # (p*k_pad,)
    cols = jnp.transpose(w_all, (0, 2, 1)).reshape(-1, w_all.shape[1])  # (p*k_pad, n)
    point_mask = jnp.tile(active, n_perturbs)  # (p*k_pad,)
    # one streamed dist-sums pass yields both statistics: mean over active
    # points and NMFk's per-cluster min over active clusters
    s = silhouette_samples_masked(
        cols, labels, num_clusters=k_pad, point_mask=point_mask, use_kernel=use_kernel
    )
    sil_mean = jnp.sum(s) / jnp.maximum(jnp.sum(point_mask), 1.0)
    onehot = jax.nn.one_hot(labels, k_pad, dtype=cols.dtype) * point_mask[:, None]
    sizes = jnp.sum(onehot, axis=0)
    per_cluster = (onehot.T @ s) / jnp.maximum(sizes, 1.0)
    min_sil = jnp.min(jnp.where(active, per_cluster, jnp.inf))
    # k=1: single cluster, silhouette undefined -> 1.0 (stable)
    min_sil = jnp.where(k_eff > 1, min_sil, 1.0)
    sil_mean = jnp.where(k_eff > 1, sil_mean, 1.0)
    return NMFkScore(min_sil, sil_mean, jnp.mean(errs))


@functools.partial(jax.jit, static_argnames=("k_pad", "n_perturbs", "nmf_iters", "use_kernel"))
def _nmfk_score_masked(
    v: Array,
    k_eff: Array,
    key: Array,
    k_pad: int,
    n_perturbs: int = 8,
    nmf_iters: int = 150,
    epsilon: float = 0.015,
    use_kernel: bool = False,
) -> NMFkScore:
    """``nmfk_score`` with the rank padded to k_pad and masked to k_eff.

    All shapes depend only on (k_pad, n_perturbs, nmf_iters), so one jit
    compilation serves every rank in a wavefront batch. At k_eff == k_pad
    the perturbation and init draws coincide with ``nmfk_score``'s.
    """
    kp, kf = jax.random.split(key)
    pkeys = jax.random.split(kp, n_perturbs)
    fkeys = jax.random.split(kf, n_perturbs)

    def fit_one(pk, fk):
        vp = _perturb(pk, v, epsilon)
        res = _nmf_masked(vp, k_eff, fk, k_pad, iters=nmf_iters)
        return res.w, res.rel_error

    w_all, errs = jax.vmap(fit_one)(pkeys, fkeys)  # (p, n, k_pad), (p,)
    return _pooled_w_score(w_all, errs, k_eff, k_pad, n_perturbs, use_kernel)


def _nmfk_score_masked_dist(
    v_l: Array,
    k_eff: Array,
    key: Array,
    k_pad: int,
    data_axis: str,
    n_total: int,
    n_perturbs: int = 8,
    nmf_iters: int = 150,
    epsilon: float = 0.015,
    use_kernel: bool = False,
    comm: str = "sync",
) -> NMFkScore:
    """``_nmfk_score_masked`` with the fit row-distributed over ``data_axis``.

    Runs inside a shard_map body: v_l is this shard's row block. Each
    perturbation draws the *full* (n, m) noise matrix from the replicated
    key and slices its rows, so the fit consumes exactly the draws the
    single-device path consumes; the NMF itself is ``_dnmf_masked_local``
    (pyDNMFk psum structure; ``comm="pipelined"`` overlaps its Gram
    reductions with the local W-update). W is all-gathered (n×k_pad per
    perturbation — tiny next to V) and the pooled-column scoring runs
    replicated.
    """
    from .distributed import _dnmf_masked_local

    n_l, m = v_l.shape
    idx = jax.lax.axis_index(data_axis)
    kp, kf = jax.random.split(key)
    pkeys = jax.random.split(kp, n_perturbs)
    fkeys = jax.random.split(kf, n_perturbs)

    def fit_one(pk, fk):
        noise = jax.random.uniform(
            pk, (n_total, m), v_l.dtype, 1.0 - epsilon, 1.0 + epsilon
        )
        vp_l = v_l * jax.lax.dynamic_slice_in_dim(noise, idx * n_l, n_l, axis=0)
        return _dnmf_masked_local(
            vp_l, k_eff, fk, k_pad, iters=nmf_iters, axis=data_axis,
            n_total=n_total, comm=comm,
        )

    w_all_l, errs = jax.vmap(fit_one)(pkeys, fkeys)  # (p, n_l, k_pad), (p,)
    w_all = jax.lax.all_gather(w_all_l, data_axis, axis=1, tiled=True)  # (p, n, k_pad)
    return _pooled_w_score(w_all, errs, k_eff, k_pad, n_perturbs, use_kernel)


def nmfk_score_batched(
    v: Array,
    ks: Sequence[int],
    key: Array,
    k_pad: int | None = None,
    n_perturbs: int = 8,
    nmf_iters: int = 150,
    epsilon: float = 0.015,
    use_kernel: bool = False,
) -> NMFkScore:
    """Score every rank in ``ks`` as one padded vmapped NMFk ensemble.

    Returns an NMFkScore whose fields carry a leading batch axis aligned
    with ``ks``. Lane i uses ``fold_in(key, ks[i])`` — the same key schedule
    as ``make_nmfk_evaluator`` — so at k_pad == ks[i] the scalar and batched
    scores coincide.
    """
    ks_arr, keys, k_pad = batched_lanes(ks, key, k_pad)
    return jax.vmap(
        lambda k_eff, sub: _nmfk_score_masked(
            v,
            k_eff,
            sub,
            k_pad,
            n_perturbs=n_perturbs,
            nmf_iters=nmf_iters,
            epsilon=epsilon,
            use_kernel=use_kernel,
        )
    )(ks_arr, keys)


@functools.lru_cache(maxsize=64)
def _sharded_score_fn(
    mesh,
    k_pad: int,
    n_perturbs: int,
    nmf_iters: int,
    epsilon: float,
    use_kernel: bool,
    lane_axis: str,
    data_axis: str,
    comm: str = "sync",
):
    """Build (once per config) the jitted shard_map'd wave scorer.

    The returned callable takes ``(ks_arr, keys, v)`` and is cached so every
    wave of the same padded batch shape reuses one compiled executable —
    rebuilding the shard_map per call would defeat the jit cache entirely.
    """
    from jax.sharding import PartitionSpec as P

    from .distributed import shard_map

    shape = dict(mesh.shape)
    data = shape.get(data_axis, 1)

    if data == 1:
        def body(ks_l, keys_l, v):
            return jax.vmap(
                lambda k_eff, sub: _nmfk_score_masked(
                    v, k_eff, sub, k_pad,
                    n_perturbs=n_perturbs, nmf_iters=nmf_iters,
                    epsilon=epsilon, use_kernel=use_kernel,
                )
            )(ks_l, keys_l)

        in_specs = (P(lane_axis), P(lane_axis, None), P())
    else:
        def body(ks_l, keys_l, v_l):
            n_total = v_l.shape[0] * data
            return jax.vmap(
                lambda k_eff, sub: _nmfk_score_masked_dist(
                    v_l, k_eff, sub, k_pad, data_axis, n_total,
                    n_perturbs=n_perturbs, nmf_iters=nmf_iters,
                    epsilon=epsilon, use_kernel=use_kernel, comm=comm,
                )
            )(ks_l, keys_l)

        in_specs = (P(lane_axis), P(lane_axis, None), P(data_axis, None))

    out_specs = NMFkScore(P(lane_axis), P(lane_axis), P(lane_axis))
    # data-sharded scores are replicated over the data axis (all_gather'd W,
    # psum'd errors) but rep inference can't see through the RNG draws
    return jax.jit(shard_map(body, mesh, in_specs, out_specs, check_rep=(data == 1)))


def nmfk_score_sharded(
    v: Array,
    ks: Sequence[int],
    key: Array,
    mesh,
    k_pad: int | None = None,
    n_perturbs: int = 8,
    nmf_iters: int = 150,
    epsilon: float = 0.015,
    use_kernel: bool = False,
    lane_axis: str = "lane",
    data_axis: str = "data",
    comm: str = "sync",
) -> NMFkScore:
    """``nmfk_score_batched`` sharded over a 2-D ``Mesh((lane, data))``.

    The wave's k axis is split over ``lane_axis`` (each device group fits a
    disjoint slice of the ensemble); when the mesh has a non-trivial
    ``data_axis``, V's rows are additionally sharded over it and each fit
    runs the pyDNMFk psum structure — the paper's parallel-over-k ×
    distributed-within-k composition in one jit'd dispatch. The key
    schedule is lane i = ``fold_in(key, ks[i])``, identical to the batched
    and scalar paths, so scores agree with ``nmfk_score_batched`` (exactly
    for lane-only meshes; to psum reduction order under data sharding;
    ``comm="pipelined"`` additionally runs the one-sweep-stale overlapped
    Gram schedule inside each data-sharded fit — same ``k_optimal``,
    scores within the conformance suite's documented tolerance).

    Requires len(ks) divisible by the lane count (planes guarantee this by
    bucketing the batch to a lane multiple) and, when data > 1, v's row
    count divisible by the data-axis size.
    """
    from .distributed import COMM_MODES

    if comm not in COMM_MODES:
        raise ValueError(f"comm must be one of {COMM_MODES}, got {comm!r}")
    ks_arr, keys, k_pad = batched_lanes(ks, key, k_pad)
    shape = dict(mesh.shape)
    lanes = shape[lane_axis]
    data = shape.get(data_axis, 1)
    if ks_arr.shape[0] % lanes:
        raise ValueError(
            f"wave size {ks_arr.shape[0]} not divisible by lane count {lanes}"
        )
    if data > 1 and v.shape[0] % data:
        raise ValueError(
            f"v rows {v.shape[0]} not divisible by data-axis size {data}"
        )
    fn = _sharded_score_fn(
        mesh, int(k_pad), int(n_perturbs), int(nmf_iters), float(epsilon),
        bool(use_kernel), lane_axis, data_axis, str(comm),
    )
    return fn(ks_arr, keys, v)


# ---------------------------------------------------------------------------
# elastic lane kernels: chunked convergence-gated fits with warm starts
# ---------------------------------------------------------------------------
# The elastic executor schedules *fit-chunks*, not whole fits: one lane is
# one perturbation fit of one k, advanced ``chunk`` MU sweeps per dispatch.
# The kernels below are the device-side lane lifecycle — cold/warm init,
# resumable chunk (single-device and mesh-sharded), and the pooled-column
# scoring of a completed ensemble. Cold-started lanes are draw-for-draw
# identical to ``_nmfk_score_masked``'s inner fits, so a lane that runs to
# the full sweep budget reproduces the fixed-iteration batched plane's
# factors chunk boundaries notwithstanding.


def elastic_lane_keys(key: Array, k: int, n_perturbs: int) -> tuple[Array, Array]:
    """Per-perturbation (pkeys, fkeys) for k — ``_nmfk_score_masked``'s
    schedule under the planes' ``fold_in(key, k)`` convention."""
    kp, kf = jax.random.split(jax.random.fold_in(key, k))
    return jax.random.split(kp, n_perturbs), jax.random.split(kf, n_perturbs)


@functools.partial(jax.jit, static_argnames=("k_pad", "epsilon"))
def elastic_lane_init(
    v: Array, k_eff: Array, pkey: Array, fkey: Array, k_pad: int, epsilon: float
) -> tuple[Array, Array]:
    """Cold lane init: the exact (W, H) a masked fit of perturbation
    ``pkey`` / init ``fkey`` starts from."""
    from .nmf import _masked_init

    vp = _perturb(pkey, v, epsilon)
    return _masked_init(vp, k_eff, fkey, k_pad)


@functools.partial(jax.jit, static_argnames=("k_pad", "epsilon"))
def elastic_lane_warm_init(
    v: Array,
    k_eff: Array,
    pkey: Array,
    fkey: Array,
    w_src: Array,
    k_src: Array,
    k_pad: int,
    epsilon: float,
) -> tuple[Array, Array]:
    """Warm lane init from a completed neighbor's W (cross-k warm start).

    The first ``min(k_eff, k_src)`` columns of the cold-draw W are replaced
    by the source fit's columns, L2-renormalized to the cold draw's column
    norms so the init's magnitude statistics (and the MU updates' scale
    balance against the fresh H) are preserved; extra columns (k_eff >
    k_src) and H keep their cold draws. Zero source columns fall back to
    the cold draw — a zeroed column is unrecoverable under Lee-Seung.
    """
    from .nmf import _masked_init

    vp = _perturb(pkey, v, epsilon)
    w0, h0 = _masked_init(vp, k_eff, fkey, k_pad)
    take = jnp.arange(k_pad) < jnp.minimum(k_eff, k_src)
    src_norm = jnp.linalg.norm(w_src, axis=0, keepdims=True)
    unit = w_src / jnp.maximum(src_norm, 1e-12)
    tgt_norm = jnp.linalg.norm(w0, axis=0, keepdims=True)
    w = jnp.where((take & (src_norm[0] > 1e-12))[None, :], unit * tgt_norm, w0)
    return w, h0


@functools.partial(jax.jit, static_argnames=("k_pad", "chunk", "epsilon", "use_kernel"))
def elastic_chunk(
    v: Array,
    w: Array,
    h: Array,
    k_eff: Array,
    steps: Array,
    pkeys: Array,
    k_pad: int,
    chunk: int,
    epsilon: float,
    use_kernel: bool = False,
) -> tuple[Array, Array, Array]:
    """Advance a batch of lanes up to ``chunk`` masked MU sweeps (one dispatch).

    w (L, n, k_pad) / h (L, k_pad, m) / k_eff (L,) / steps (L,) / pkeys
    (L, 2). Lane i applies exactly ``steps[i] <= chunk`` sweeps inside the
    fixed compiled shape (lanes near their sweep budget trim their final
    chunk without a fresh compilation). Each lane regenerates its perturbed
    V from its pkey (cheaper than holding L perturbed copies of V in device
    memory) and reports the rel_error against it — the convergence signal
    the tol gate tests host-side.
    """
    from .nmf import _masked_sweeps

    def lane(w_i, h_i, k_i, st, pk):
        vp = _perturb(pk, v, epsilon)
        return _masked_sweeps(
            vp, w_i, h_i, k_i, k_pad, chunk, use_kernel=use_kernel, steps=st
        )

    return jax.vmap(lane)(w, h, k_eff, steps, pkeys)


@functools.lru_cache(maxsize=64)
def _elastic_chunk_sharded_fn(
    mesh,
    k_pad: int,
    chunk: int,
    epsilon: float,
    use_kernel: bool,
    lane_axis: str,
    data_axis: str,
    comm: str,
):
    """Build (once per config) the jitted shard_map'd elastic chunk step.

    Lanes split over ``lane_axis``; with a non-trivial ``data_axis`` each
    lane's rows (of both V and its W block) are additionally sharded and
    the chunk runs the psum'd Gram structure of ``_dnmf_masked_chunk_local``
    — the convergence residual is assembled from the same psums, so the tol
    gate under data sharding costs one scalar all-reduce pair per chunk.
    """
    from jax.sharding import PartitionSpec as P

    from .distributed import _dnmf_masked_chunk_local, shard_map
    from .nmf import _masked_sweeps

    shape = dict(mesh.shape)
    data = shape.get(data_axis, 1)

    if data == 1:
        def body(w_b, h_b, k_b, st_b, pk_b, v):
            def lane(w_i, h_i, k_i, st, pk):
                vp = _perturb(pk, v, epsilon)
                return _masked_sweeps(
                    vp, w_i, h_i, k_i, k_pad, chunk, use_kernel=use_kernel, steps=st
                )

            return jax.vmap(lane)(w_b, h_b, k_b, st_b, pk_b)

        in_specs = (
            P(lane_axis), P(lane_axis), P(lane_axis), P(lane_axis), P(lane_axis, None), P(),
        )
        out_specs = (P(lane_axis), P(lane_axis), P(lane_axis))
    else:
        def body(w_b, h_b, k_b, st_b, pk_b, v_l):
            n_l, m = v_l.shape
            n_total = n_l * data
            idx = jax.lax.axis_index(data_axis)

            def lane(w_l, h_l, k_i, st, pk):
                noise = jax.random.uniform(
                    pk, (n_total, m), v_l.dtype, 1.0 - epsilon, 1.0 + epsilon
                )
                vp_l = v_l * jax.lax.dynamic_slice_in_dim(noise, idx * n_l, n_l, axis=0)
                return _dnmf_masked_chunk_local(
                    vp_l, w_l, h_l, k_i, k_pad, chunk, data_axis, data,
                    comm=comm, steps=st,
                )

            return jax.vmap(lane)(w_b, h_b, k_b, st_b, pk_b)

        in_specs = (
            P(lane_axis, data_axis), P(lane_axis), P(lane_axis), P(lane_axis),
            P(lane_axis, None), P(data_axis, None),
        )
        # h and err are replicated over data (psum'd Grams / residual) but
        # the RNG draws defeat replication inference
        out_specs = (P(lane_axis, data_axis), P(lane_axis), P(lane_axis))

    return jax.jit(shard_map(body, mesh, in_specs, out_specs, check_rep=(data == 1)))


def elastic_chunk_sharded(
    v: Array,
    w: Array,
    h: Array,
    k_eff: Array,
    steps: Array,
    pkeys: Array,
    mesh,
    k_pad: int,
    chunk: int,
    epsilon: float,
    use_kernel: bool = False,
    lane_axis: str = "lane",
    data_axis: str = "data",
    comm: str = "sync",
) -> tuple[Array, Array, Array]:
    """``elastic_chunk`` sharded over a 2-D ``Mesh((lane, data))``.

    Requires the lane batch divisible by the lane count and, when data > 1,
    v's rows divisible by the data-axis size (the elastic plane's slot
    bucketing guarantees the former).
    """
    lanes = dict(mesh.shape)[lane_axis]
    if w.shape[0] % lanes:
        raise ValueError(f"lane batch {w.shape[0]} not divisible by lane count {lanes}")
    fn = _elastic_chunk_sharded_fn(
        mesh, int(k_pad), int(chunk), float(epsilon), bool(use_kernel),
        lane_axis, data_axis, str(comm),
    )
    return fn(w, h, k_eff, steps, pkeys, v)


@functools.partial(jax.jit, static_argnames=("k_pad", "n_perturbs", "use_kernel"))
def elastic_pooled_score(
    w_all: Array,
    errs: Array,
    k_eff: Array,
    k_pad: int,
    n_perturbs: int,
    use_kernel: bool = False,
) -> NMFkScore:
    """Score a completed lane ensemble (p, n, k_pad) — the shared pooled-
    column silhouette tail, jitted once per (k_pad, n_perturbs)."""
    return _pooled_w_score(w_all, errs, k_eff, k_pad, n_perturbs, use_kernel)


def make_nmfk_evaluator(
    v: Array,
    key: Array,
    n_perturbs: int = 8,
    nmf_iters: int = 150,
    epsilon: float = 0.015,
    statistic: str = "min",
    use_kernel: bool = False,
) -> Callable[[int], float]:
    """Binary Bleed ``evaluate(k)`` closure over a dataset."""

    def evaluate(k: int, should_abort=None) -> float:
        del should_abort  # jit'd fast path has no chunk boundary to poll
        sub = jax.random.fold_in(key, k)
        sc = nmfk_score(
            v,
            int(k),
            sub,
            n_perturbs=n_perturbs,
            nmf_iters=nmf_iters,
            epsilon=epsilon,
            use_kernel=use_kernel,
        )
        return float(sc.min_silhouette if statistic == "min" else sc.mean_silhouette)

    return evaluate
