"""Nonnegative RESCAL via multiplicative updates (paper's pyDRESCALk model).

X (r, n, n) ≈ A R_r A^T with A (n, k) >= 0, R_r (k, k) >= 0.

MU updates (Frobenius objective, nonnegative RESCAL):

    A <- A * Σ_r (X_r A R_r^T + X_r^T A R_r)
             / Σ_r (A R_r A^T A R_r^T + A R_r^T A^T A R_r)        (+ eps)
    R_r <- R_r * (A^T X_r A) / (A^T A R_r A^T A + eps)

RESCALk scoring mirrors NMFk: perturbation ensemble, align A columns,
silhouette stability + relative error.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.scoring import silhouette_score

Array = jax.Array
_EPS = 1e-9


class RESCALResult(NamedTuple):
    a: Array  # (n, k)
    r: Array  # (nr, k, k)
    rel_error: Array


def _init(key: Array, n: int, nr: int, k: int, x_mean: Array, dtype):
    ka, kr = jax.random.split(key)
    scale = jnp.sqrt(jnp.maximum(x_mean, _EPS)) / k
    a = scale * jax.random.uniform(ka, (n, k), dtype, 0.1, 1.0)
    r = scale * jax.random.uniform(kr, (nr, k, k), dtype, 0.1, 1.0)
    return a, r


def rescal_step(x: Array, a: Array, r: Array) -> tuple[Array, Array]:
    """One MU sweep (A then R)."""
    ata = a.T @ a  # (k, k)
    # A update
    num = jnp.einsum("rij,jl,rkl->ik", x, a, r) + jnp.einsum("rji,jl,rlk->ik", x, a, r)
    arat = jnp.einsum("rkl,lm,rnm->rkn", r, ata, r)  # R_r A^T A R_r^T
    arat2 = jnp.einsum("rlk,lm,rmn->rkn", r, ata, r)  # R_r^T A^T A R_r
    den = a @ jnp.sum(arat + arat2, axis=0)
    a = a * num / (den + _EPS)
    # R update
    ata = a.T @ a
    num_r = jnp.einsum("li,rlm,mj->rij", a, x, a)  # A^T X_r A
    den_r = jnp.einsum("ik,rkl,lj->rij", ata, r, ata)
    r = r * num_r / (den_r + _EPS)
    return a, r


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def rescal(x: Array, k: int, key: Array, iters: int = 150) -> RESCALResult:
    nr, n, _ = x.shape
    a, r = _init(key, n, nr, k, jnp.mean(x), x.dtype)

    def body(_, ar):
        return rescal_step(x, *ar)

    a, r = jax.lax.fori_loop(0, iters, body, (a, r))
    recon = jnp.einsum("ik,rkl,jl->rij", a, r, a)
    err = jnp.linalg.norm(x - recon) / jnp.maximum(jnp.linalg.norm(x), _EPS)
    return RESCALResult(a, r, err)


@functools.partial(jax.jit, static_argnames=("k", "n_perturbs", "iters"))
def rescalk_score(
    x: Array,
    k: int,
    key: Array,
    n_perturbs: int = 6,
    iters: int = 120,
    epsilon: float = 0.015,
) -> tuple[Array, Array]:
    """(min cluster silhouette of A-column ensemble, mean rel_error)."""
    kp, kf = jax.random.split(key)
    pkeys = jax.random.split(kp, n_perturbs)
    fkeys = jax.random.split(kf, n_perturbs)

    def fit_one(pk, fk):
        xp = x * jax.random.uniform(pk, x.shape, x.dtype, 1.0 - epsilon, 1.0 + epsilon)
        res = rescal(xp, k, fk, iters=iters)
        return res.a, res.rel_error

    a_all, errs = jax.vmap(fit_one)(pkeys, fkeys)  # (p, n, k)
    a_all = a_all / jnp.maximum(jnp.linalg.norm(a_all, axis=1, keepdims=True), 1e-12)

    # greedy column alignment against perturbation 0 (same as NMFk)
    ref = a_all[0]

    def match_one(a_p):
        sim = ref.T @ a_p

        def body(_, carry):
            assign, sim_m = carry
            flat = jnp.argmax(sim_m)
            i, j = flat // k, flat % k
            assign = assign.at[j].set(i)
            sim_m = sim_m.at[i, :].set(-jnp.inf).at[:, j].set(-jnp.inf)
            return assign, sim_m

        assign, _ = jax.lax.fori_loop(0, k, body, (jnp.zeros((k,), jnp.int32), sim))
        return assign

    labels = jax.vmap(match_one)(a_all).reshape(-1)
    cols = jnp.transpose(a_all, (0, 2, 1)).reshape(-1, x.shape[1])
    sil = silhouette_score(cols, labels, num_clusters=k)
    sil = jnp.where(k > 1, sil, 1.0)
    return sil, jnp.mean(errs)


def make_rescalk_evaluator(
    x: Array, key: Array, n_perturbs: int = 6, iters: int = 120
) -> Callable[[int], float]:
    def evaluate(k: int, should_abort=None) -> float:
        del should_abort
        sub = jax.random.fold_in(key, k)
        sil, _ = rescalk_score(x, int(k), sub, n_perturbs=n_perturbs, iters=iters)
        return float(sil)

    return evaluate
