"""Fault-tolerant checkpointing: atomic, manifest-verified, async-capable.

Layout per step:
    <root>/step_000123.tmp/...   (write)
    <root>/step_000123/          (atomic rename on completion)
        manifest.json            {step, tree structure, leaf checksums}
        arr_00000.npy ...        one file per leaf (np.save, mmap-friendly)

Restore picks the newest COMPLETE checkpoint (manifest present + all leaf
files verified by size) — a writer killed mid-save can never corrupt
restart state. ``AsyncCheckpointer`` runs saves on a worker thread with a
bounded queue (back-pressure instead of unbounded host memory).

The k-search journal (core.coordinator.FileCoordinator) composes with this:
model fits checkpoint here, the search frontier checkpoints there.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(root: str, step: int, tree: PyTree) -> str:
    """Blocking atomic save. Returns the final directory."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten_with_paths(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.ascontiguousarray(np.asarray(leaf))
        path = os.path.join(tmp, f"arr_{i:05d}.npy")
        # store raw bytes: numpy can't round-trip ml_dtypes (bfloat16 etc.)
        np.save(path, arr.view(np.uint8).reshape(-1))
        manifest["leaves"].append(
            {"file": f"arr_{i:05d}.npy", "shape": list(arr.shape), "dtype": str(arr.dtype),
             "bytes": int(arr.nbytes)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return final


def _is_complete(d: str) -> bool:
    man = os.path.join(d, "manifest.json")
    if not os.path.exists(man):
        return False
    try:
        with open(man) as f:
            m = json.load(f)
        for leaf in m["leaves"]:
            p = os.path.join(d, leaf["file"])
            if not os.path.exists(p):
                return False
        return True
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            d = os.path.join(root, name)
            if _is_complete(d):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(root: str, like: PyTree, step: int | None = None) -> tuple[PyTree, int]:
    """Restore into the structure of `like` (shapes/dtypes verified)."""
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    leaves, treedef = _flatten_with_paths(like)
    out = []
    for i, leaf in enumerate(leaves):
        raw = np.load(os.path.join(d, f"arr_{i:05d}.npy"))
        want = np.asarray(leaf)
        if raw.nbytes != want.nbytes:
            raise ValueError(
                f"leaf {i}: checkpoint has {raw.nbytes} bytes, expected "
                f"{want.nbytes} for shape {want.shape} {want.dtype}"
            )
        out.append(raw.view(want.dtype).reshape(want.shape))
    return treedef.unflatten(out), step


def prune_old(root: str, keep: int = 3) -> None:
    if not os.path.isdir(root):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(root)
        if n.startswith("step_") and not n.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread saver with bounded queue (depth 1: latest wins)."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: BaseException | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.root, step, tree)
                prune_old(self.root, self.keep)
            except BaseException as e:  # surfaced on next submit/close
                self._err = e

    def submit(self, step: int, tree: PyTree) -> None:
        if self._err:
            raise self._err
        # materialize on host BEFORE queuing so device buffers can be freed
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        try:
            self._q.put_nowait((step, host_tree))
        except queue.Full:
            # drop the older pending save — latest state wins
            try:
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._q.put_nowait((step, host_tree))

    def close(self) -> None:
        self._q.put(None)
        self._thread.join(timeout=60)
        if self._err:
            raise self._err
