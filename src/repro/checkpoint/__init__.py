from .checkpointer import AsyncCheckpointer, latest_step, prune_old, restore, save  # noqa: F401
