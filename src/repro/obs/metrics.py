"""Process-local metrics registry for the live search path.

Counters, gauges, and histograms created on first use by name, mutated
under one registry lock (increments bracket model fits — contention is
nil), and rolled up by ``summary()`` into a JSON-safe dict whose
``search`` block derives the paper's headline number from live accounting:

    visit_fraction = ks_visited / ks_candidates

i.e. the fraction of the k grid Binary Bleed actually evaluated vs. the
naive grid search's 1.0 — previously only available from the offline
``SimulatedScheduler``, now measured on every instrumented run.

Conventional names used across the instrumented layers:

  counters   ks_visited, ks_skipped, ks_aborted, ks_journaled,
             compile_count, publish_count, bound_merges, lock_broken,
             speculations, failures, joins,
             sweeps_run / sweeps_saved / sweeps_fixed_total (the elastic
             executor's MU-sweep accounting: run + saved == fixed_total),
             warm_start_hits (elastic lanes seeded from a neighbor's W)
  gauges     ks_candidates, heartbeat_age_max, lo_bound, hi_bound,
             lane_utilization (real / dispatched lanes of the last wave),
             lane_occupancy (occupied / dispatched lanes of the last
             elastic chunk)
  histograms wave_size, fit_seconds, publish_latency_s, lock_wait_s,
             lane_utilization (per-dispatch distribution),
             lane_occupancy (per-chunk distribution)
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Iterator

_HIST_CAP = 4096  # values kept for percentiles; count/sum/min/max stay exact


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float | None = None


class Histogram:
    __slots__ = ("count", "total", "min", "max", "values")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.values: list[float] = []

    def _observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if len(self.values) < _HIST_CAP:
            self.values.append(v)

    def percentile(self, q: float) -> float | None:
        if not self.values:
            return None
        vals = sorted(self.values)
        idx = min(int(q * (len(vals) - 1) + 0.5), len(vals) - 1)
        return vals[idx]


def _finite(v: float | None) -> float | None:
    """JSON-safe: non-finite values become None (json.dump stays strict)."""
    if v is None or not math.isfinite(v):
        return None
    return float(v)


class Metrics:
    """Registry of named counters/gauges/histograms (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    # -- mutation ---------------------------------------------------------------
    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            c.value += n

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            g.value = value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h._observe(float(value))

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    # -- reads ------------------------------------------------------------------
    def counter(self, name: str) -> int:
        with self._lock:
            c = self._counters.get(name)
            return c.value if c is not None else 0

    def gauge(self, name: str) -> float | None:
        with self._lock:
            g = self._gauges.get(name)
            return g.value if g is not None else None

    def histogram(self, name: str) -> dict | None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                return None
            return self._hist_summary(h)

    @staticmethod
    def _hist_summary(h: Histogram) -> dict:
        mean = h.total / h.count if h.count else None
        return {
            "count": h.count,
            "sum": _finite(h.total),
            "mean": _finite(mean) if mean is not None else None,
            "min": _finite(h.min),
            "max": _finite(h.max),
            "p50": _finite(h.percentile(0.50)),
            "p95": _finite(h.percentile(0.95)),
        }

    def summary(self) -> dict:
        """JSON-safe rollup + the derived pruning-efficiency ``search`` block."""
        with self._lock:
            counters = {k: c.value for k, c in sorted(self._counters.items())}
            gauges = {k: _finite(g.value) for k, g in sorted(self._gauges.items())}
            hists = {k: self._hist_summary(h) for k, h in sorted(self._hists.items())}
        visited = counters.get("ks_visited", 0)
        skipped = counters.get("ks_skipped", 0)
        aborted = counters.get("ks_aborted", 0)
        candidates = gauges.get("ks_candidates")
        visit_fraction = None
        if candidates:
            visit_fraction = visited / candidates
        search = {
            "ks_candidates": int(candidates) if candidates is not None else None,
            "ks_visited": visited,
            "ks_skipped": skipped,
            "ks_aborted": aborted,
            # headline: fraction of the grid evaluated (naive grid search = 1.0)
            "visit_fraction": _finite(visit_fraction) if visit_fraction is not None else None,
            "saved_vs_grid": _finite(1.0 - visit_fraction) if visit_fraction is not None else None,
            "compile_count": counters.get("compile_count", 0),
            "publish_count": counters.get("publish_count", 0),
        }
        if counters.get("sweeps_fixed_total"):
            # elastic executor ran: surface the sweep-level savings next to
            # the k-level visit fraction (both are fractions of naive work)
            run = counters.get("sweeps_run", 0)
            fixed = counters["sweeps_fixed_total"]
            search["sweeps_run"] = run
            search["sweeps_saved"] = counters.get("sweeps_saved", 0)
            search["sweeps_fixed_total"] = fixed
            search["sweep_fraction"] = _finite(run / fixed)
            search["warm_start_hits"] = counters.get("warm_start_hits", 0)
        return {"search": search, "counters": counters, "gauges": gauges, "histograms": hists}


# -- process default ------------------------------------------------------------
_default_metrics = Metrics()
_default_lock = threading.Lock()


def get_metrics() -> Metrics:
    """The process-default registry (always live — metrics are cheap)."""
    return _default_metrics


def set_metrics(metrics: Metrics) -> Metrics:
    """Install ``metrics`` as the process default; returns the previous one."""
    global _default_metrics
    with _default_lock:
        prev = _default_metrics
        _default_metrics = metrics
    return prev


@contextlib.contextmanager
def use_metrics(metrics: Metrics) -> Iterator[Metrics]:
    """Scoped ``set_metrics``: restores the previous default on exit."""
    prev = set_metrics(metrics)
    try:
        yield metrics
    finally:
        set_metrics(prev)


__all__ = ["Metrics", "Counter", "Gauge", "Histogram", "get_metrics", "set_metrics", "use_metrics"]
