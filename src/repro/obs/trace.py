"""Search-wide tracing: nested spans + events, Perfetto/JSONL export.

Design constraints (this module is imported by the hot search path):

  * **dependency-free** — stdlib only, importable from any layer;
  * **allocation-free when off** — the default tracer is a singleton
    ``NullTracer`` whose ``span()`` returns one shared no-op context
    manager and whose ``event()`` is a bare ``pass``;
  * **thread-safe when on** — workers of ``ThreadPoolScheduler`` and the
    wavefront loop append to one buffer under a lock (appends are tiny
    dicts; the model fits they bracket are milliseconds-to-minutes).

Span/event records carry a ``track`` — the timeline they belong to
("resource-3", "wavefront", "device:0"). The Perfetto export maps each
track to a Chrome-trace ``tid`` with a ``thread_name`` metadata record, so
`ui.perfetto.dev` / ``chrome://tracing`` render one lane per resource.

Timestamps are microseconds relative to the tracer's creation
(``time.perf_counter`` based, injectable for tests). Simulated schedules
(logical time) inject spans directly via ``add_span`` — see
``ScheduleTrace.to_tracer``.
"""
from __future__ import annotations

import contextlib
import json
import math
import threading
import time
from typing import Any, Callable, Iterator


def _json_safe(v: Any) -> Any:
    """Strict-JSON attr values: ±inf/nan become strings, odd types str()."""
    if isinstance(v, float) and not math.isfinite(v):
        return str(v)
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    return str(v)


class Span:
    """One timed region; a context manager handed out by ``Tracer.span``."""

    __slots__ = ("name", "track", "attrs", "ts_us", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, track: str | None, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.attrs = attrs
        self.ts_us = 0.0

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes discovered mid-span (e.g. the score)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self.ts_us = self._tracer._now_us()
        return self

    def __exit__(self, *exc) -> None:
        self._tracer._complete(self)


class _NullSpan:
    """Shared no-op span: zero allocations on the disabled path."""

    __slots__ = ()

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every operation is a no-op, nothing is buffered."""

    enabled = False

    def span(self, name: str, track: str | None = None, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def event(self, name: str, track: str | None = None, **attrs: Any) -> None:
        pass

    def add_span(
        self, name: str, ts_us: float, dur_us: float, track: str | None = None, **attrs: Any
    ) -> None:
        pass

    def add_event(self, name: str, ts_us: float, track: str | None = None, **attrs: Any) -> None:
        pass

    def now_us(self) -> float:
        return 0.0

    def events(self) -> list[dict]:
        return []


NULL_TRACER = NullTracer()


class Tracer:
    """Buffered, thread-safe span/event recorder.

    Records are plain dicts:
      spans  — ``{"name", "ph": "X", "ts", "dur", "track", "args"}``
      events — ``{"name", "ph": "i", "ts", "track", "args"}``
    (``ts``/``dur`` in microseconds since tracer creation.)
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        self._records: list[dict] = []

    # -- recording ------------------------------------------------------------
    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def now_us(self) -> float:
        """Current tracer-relative timestamp (µs) — pair with ``add_span``
        to inject retroactive spans (e.g. per-device lanes of a dispatch
        whose wall interval is only known after the batch completes)."""
        return self._now_us()

    def _complete(self, span: Span) -> None:
        end = self._now_us()
        rec = {
            "name": span.name,
            "ph": "X",
            "ts": span.ts_us,
            "dur": max(end - span.ts_us, 0.0),
            "track": span.track if span.track is not None else _current_track(),
            "args": span.attrs,
        }
        with self._lock:
            self._records.append(rec)

    def span(self, name: str, track: str | None = None, **attrs: Any) -> Span:
        return Span(self, name, track, attrs)

    def event(self, name: str, track: str | None = None, **attrs: Any) -> None:
        rec = {
            "name": name,
            "ph": "i",
            "ts": self._now_us(),
            "track": track if track is not None else _current_track(),
            "args": attrs,
        }
        with self._lock:
            self._records.append(rec)

    # manual injection (simulated schedules replaying logical time)
    def add_span(
        self, name: str, ts_us: float, dur_us: float, track: str | None = None, **attrs: Any
    ) -> None:
        rec = {
            "name": name,
            "ph": "X",
            "ts": float(ts_us),
            "dur": max(float(dur_us), 0.0),
            "track": track if track is not None else _current_track(),
            "args": attrs,
        }
        with self._lock:
            self._records.append(rec)

    def add_event(self, name: str, ts_us: float, track: str | None = None, **attrs: Any) -> None:
        rec = {
            "name": name,
            "ph": "i",
            "ts": float(ts_us),
            "track": track if track is not None else _current_track(),
            "args": attrs,
        }
        with self._lock:
            self._records.append(rec)

    # -- inspection / export ----------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._records)

    def clear(self) -> None:
        with self._lock:
            self._records.clear()

    def export_jsonl(self, path: str) -> int:
        """One JSON record per line; returns the number of records written."""
        recs = self.events()
        with open(path, "w") as f:
            for rec in recs:
                f.write(json.dumps({**rec, "args": _json_safe(rec["args"])}) + "\n")
        return len(recs)

    def to_chrome_trace(self) -> dict:
        """Chrome-trace/Perfetto JSON object (``{"traceEvents": [...]}``).

        Tracks become tids (first-seen order) with ``thread_name`` metadata
        so Perfetto shows one named lane per resource/worker.
        """
        recs = self.events()
        tids: dict[str, int] = {}
        out: list[dict] = []
        for rec in recs:
            track = str(rec["track"])
            if track not in tids:
                tids[track] = len(tids) + 1
                out.append(
                    {
                        "name": "thread_name",
                        "ph": "M",
                        "pid": 1,
                        "tid": tids[track],
                        "args": {"name": track},
                    }
                )
            ev = {
                "name": rec["name"],
                "ph": rec["ph"],
                "ts": rec["ts"],
                "pid": 1,
                "tid": tids[track],
                "cat": "search",
                "args": _json_safe(rec["args"]),
            }
            if rec["ph"] == "X":
                ev["dur"] = rec["dur"]
            else:
                ev["s"] = "t"  # instant scope: thread
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export_perfetto(self, path: str) -> int:
        """Write Chrome-trace JSON loadable by ui.perfetto.dev; returns #events."""
        trace = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return len(trace["traceEvents"])


def _current_track() -> str:
    """Default track: the current thread (workers get their own lane)."""
    t = threading.current_thread()
    return "main" if t is threading.main_thread() else t.name


# -- process default ------------------------------------------------------------
_default_tracer: NullTracer | Tracer = NULL_TRACER
_default_lock = threading.Lock()


def get_tracer() -> NullTracer | Tracer:
    """The process-default tracer (``NULL_TRACER`` unless installed)."""
    return _default_tracer


def set_tracer(tracer: NullTracer | Tracer) -> NullTracer | Tracer:
    """Install ``tracer`` as the process default; returns the previous one."""
    global _default_tracer
    with _default_lock:
        prev = _default_tracer
        _default_tracer = tracer
    return prev


@contextlib.contextmanager
def use_tracer(tracer: NullTracer | Tracer) -> Iterator[NullTracer | Tracer]:
    """Scoped ``set_tracer``: restores the previous default on exit."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


__all__ = [
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "get_tracer",
    "set_tracer",
    "use_tracer",
]
