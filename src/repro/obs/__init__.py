"""Observability for the live search path: tracing + metrics, zero deps.

The paper's value claim is operational — fewer k's visited, in-flight work
aborted, bounds shared across resources — so the reproduction carries a
search-wide telemetry layer that turns those claims into measurable spans
and counters on *live* runs, not just the offline ``SimulatedScheduler``:

  * ``repro.obs.trace`` — ``Tracer`` (nested spans + instant events,
    thread-safe, exportable as JSONL and Chrome-trace/Perfetto JSON) and
    the allocation-free ``NullTracer`` default.
  * ``repro.obs.metrics`` — a process-local registry of counters / gauges /
    histograms whose ``summary()`` derives the paper's headline number
    (visit fraction vs. naive grid search) from live accounting.

Every instrumented component resolves the process defaults at call time
(``get_tracer()`` / ``get_metrics()``), so enabling telemetry is one
``set_tracer(Tracer())`` (or the ``use_tracer`` context manager / the
``ksearch --trace`` flag) — no constructor plumbing, and the hot path pays
a single attribute read when tracing is off.
"""
from .metrics import (  # noqa: F401
    Metrics,
    get_metrics,
    set_metrics,
    use_metrics,
)
from .trace import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    Tracer,
    get_tracer,
    set_tracer,
    use_tracer,
)

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "get_tracer",
    "set_tracer",
    "use_tracer",
    "Metrics",
    "get_metrics",
    "set_metrics",
    "use_metrics",
]
