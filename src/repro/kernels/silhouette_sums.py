"""Fused streaming silhouette dist-sum Pallas kernel (TPU target).

T_scorer's silhouette reduction only ever consumes the (n, n) distance
matrix D through one contraction: ``dist_sums = sqrt(D2) @ onehot`` with
``onehot`` the (n, k) cluster membership matrix. The dense path writes D to
HBM (O(n^2) bytes, (b, n, n) for a batched wavefront) and immediately reads
it back to reduce it to (n, k) — pure memory traffic with no reuse.

This kernel never lets D leave VMEM: a two-level reduction grid
(n-tiles x m-reduction x d-reduction) builds each (bn, bm) squared-distance
tile in a VMEM accumulator over d-steps, applies ``sqrt`` in-register, and
contracts the tile against the resident (bm, k) one-hot block straight into
a (bn, k) output accumulator. HBM output traffic drops from O(n^2) to
O(n*k); input traffic is the x/y tiles plus the one-hot walk.

Masking comes for free: padded/masked points carry all-zero one-hot rows,
so their (nonzero!) distances contract to zero — the same contract as the
dense ``sqrt(pairwise) @ onehot`` with a masked one-hot. Rows of y beyond
the real m may therefore be zero-padded as long as the one-hot is padded
with zero rows to match (ops.py does both).

Alignment (bn/bm/bd tile multiples, k padded to the lane width) is handled
by the ops.py wrappers; a leading-axis batched variant serves wavefront
lanes exactly like ``pairwise_dist.pairwise_sq_dists_batched``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sil_sums_kernel(x_ref, y_ref, g_ref, out_ref, dacc_ref, oacc_ref, *, m_steps: int, d_steps: int):
    """Grid = (n_tiles, m_steps, d_steps), reductions innermost.

    dacc (bn, bm): squared-distance tile accumulated over d-steps.
    oacc (bn, k):  sqrt(dacc) @ onehot_blk accumulated over m-steps.
    """
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when((j == 0) & (s == 0))
    def _init_out():
        oacc_ref[...] = jnp.zeros_like(oacc_ref)

    @pl.when(s == 0)
    def _init_tile():
        dacc_ref[...] = jnp.zeros_like(dacc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, bd)
    y = y_ref[...].astype(jnp.float32)  # (bm, bd)
    dacc_ref[...] += (
        jax.lax.dot_general(
            x, y, dimension_numbers=(((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * -2.0
        + jnp.sum(x * x, axis=1)[:, None]
        + jnp.sum(y * y, axis=1)[None, :]
    )

    @pl.when(s == d_steps - 1)
    def _contract():
        # sqrt in-register: the distance tile dies here, never touching HBM
        dist = jnp.sqrt(jnp.maximum(dacc_ref[...], 0.0))  # (bn, bm)
        oacc_ref[...] += jax.lax.dot_general(
            dist,
            g_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when((j == m_steps - 1) & (s == d_steps - 1))
    def _finalize():
        out_ref[...] = oacc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bd", "interpret"))
def silhouette_dist_sums(
    x: jax.Array,  # (n, d)
    y: jax.Array,  # (m, d)
    onehot: jax.Array,  # (m, k) — zero rows for masked/padded points
    bn: int = 128,
    bm: int = 128,
    bd: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """out[i, c] = sum_j sqrt(||x_i - y_j||^2) * onehot[j, c], D kept in VMEM."""
    n, d = x.shape
    m, k = onehot.shape
    assert y.shape == (m, d), (y.shape, m, d)
    assert n % bn == 0 and m % bm == 0 and d % bd == 0, (n, m, d)
    m_steps = m // bm
    d_steps = d // bd
    grid = (n // bn, m_steps, d_steps)
    return pl.pallas_call(
        functools.partial(_sil_sums_kernel, m_steps=m_steps, d_steps=d_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, s: (i, s)),
            pl.BlockSpec((bm, bd), lambda i, j, s: (j, s)),
            pl.BlockSpec((bm, k), lambda i, j, s: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i, j, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        scratch_shapes=[_vmem((bn, bm)), _vmem((bn, k))],
        interpret=interpret,
    )(x, y, onehot)


def _sil_sums_batched_kernel(
    x_ref, y_ref, g_ref, out_ref, dacc_ref, oacc_ref, *, m_steps: int, d_steps: int
):
    """Grid = (batch, n_tiles, m_steps, d_steps) — the 2-D walk with a
    leading batch-lane dimension, so one launch streams every lane of a
    padded wavefront (e.g. the per-k label sets of a batched K-Means wave)."""
    j = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when((j == 0) & (s == 0))
    def _init_out():
        oacc_ref[...] = jnp.zeros_like(oacc_ref)

    @pl.when(s == 0)
    def _init_tile():
        dacc_ref[...] = jnp.zeros_like(dacc_ref)

    x = x_ref[0].astype(jnp.float32)  # (bn, bd)
    y = y_ref[0].astype(jnp.float32)  # (bm, bd)
    dacc_ref[...] += (
        jax.lax.dot_general(
            x, y, dimension_numbers=(((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * -2.0
        + jnp.sum(x * x, axis=1)[:, None]
        + jnp.sum(y * y, axis=1)[None, :]
    )

    @pl.when(s == d_steps - 1)
    def _contract():
        dist = jnp.sqrt(jnp.maximum(dacc_ref[...], 0.0))
        oacc_ref[...] += jax.lax.dot_general(
            dist,
            g_ref[0].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when((j == m_steps - 1) & (s == d_steps - 1))
    def _finalize():
        out_ref[0] = oacc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bd", "interpret"))
def silhouette_dist_sums_batched(
    x: jax.Array,  # (b, n, d)
    y: jax.Array,  # (b, m, d)
    onehot: jax.Array,  # (b, m, k)
    bn: int = 128,
    bm: int = 128,
    bd: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, n, d = x.shape
    _, m, k = onehot.shape
    assert y.shape == (b, m, d) and onehot.shape[0] == b, (x.shape, y.shape, onehot.shape)
    assert n % bn == 0 and m % bm == 0 and d % bd == 0, (b, n, m, d)
    m_steps = m // bm
    d_steps = d // bd
    grid = (b, n // bn, m_steps, d_steps)
    return pl.pallas_call(
        functools.partial(_sil_sums_batched_kernel, m_steps=m_steps, d_steps=d_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bd), lambda l, i, j, s: (l, i, s)),
            pl.BlockSpec((1, bm, bd), lambda l, i, j, s: (l, j, s)),
            pl.BlockSpec((1, bm, k), lambda l, i, j, s: (l, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, k), lambda l, i, j, s: (l, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, k), jnp.float32),
        scratch_shapes=[_vmem((bn, bm)), _vmem((bn, k))],
        interpret=interpret,
    )(x, y, onehot)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
