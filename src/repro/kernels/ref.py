"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-9


def mu_update_h_ref(v: jax.Array, w: jax.Array, h: jax.Array) -> jax.Array:
    """H <- H * (W^T V) / (W^T W H + eps), fp32 math."""
    v, w, h = (a.astype(jnp.float32) for a in (v, w, h))
    return h * (w.T @ v) / (w.T @ w @ h + _EPS)


def mu_update_w_ref(v: jax.Array, w: jax.Array, h: jax.Array) -> jax.Array:
    """W <- W * (V H^T) / (W H H^T + eps), fp32 math."""
    v, w, h = (a.astype(jnp.float32) for a in (v, w, h))
    return w * (v @ h.T) / (w @ (h @ h.T) + _EPS)


def pairwise_sq_dists_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, axis=1)[:, None]
        + jnp.sum(y * y, axis=1)[None, :]
        - 2.0 * (x @ y.T)
    )
    return jnp.maximum(d2, 0.0)


def silhouette_dist_sums_ref(x: jax.Array, onehot: jax.Array, y: jax.Array | None = None) -> jax.Array:
    """Dense oracle: materialize sqrt distances, contract with the one-hot.

    Axis-agnostic over leading batch dims — covers both the 2-D and the
    batched kernel entry points.
    """
    y = x if y is None else y
    d = jnp.sqrt(pairwise_sq_dists_nd_ref(x, y))
    return jnp.matmul(d, onehot.astype(jnp.float32))


def pairwise_sq_dists_nd_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """``pairwise_sq_dists_ref`` over optional leading batch dims."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, axis=-1)[..., :, None]
        + jnp.sum(y * y, axis=-1)[..., None, :]
        - 2.0 * jnp.matmul(x, jnp.swapaxes(y, -1, -2))
    )
    return jnp.maximum(d2, 0.0)


def attention_ref(
    q: jax.Array,  # (B, Hq, Lq, D)
    k: jax.Array,  # (B, Hk, Lk, D)
    v: jax.Array,  # (B, Hk, Lk, D)
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Dense softmax attention with GQA/causal/sliding-window, fp32 math."""
    b, hq, lq, d = q.shape
    _, hk, lk, _ = k.shape
    group = hq // hk
    scale = float(scale if scale is not None else d ** -0.5)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    q_idx = jnp.arange(lq)[:, None] + (lk - lq)  # decode offset when lq < lk
    k_idx = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
