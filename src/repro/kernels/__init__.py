"""Pallas TPU kernels for the compute hot spots (validated via interpret=True).

  * nmf_update      — fused multiplicative-update GEMM+epilogue (T_model)
  * pairwise_dist   — fused distance-matrix GEMM+norms (T_scorer)
  * silhouette_sums — streaming fused silhouette dist-sums: (n, k) cluster
                      sums with the (n, n) distance matrix kept in VMEM
  * flash_attention — causal/windowed GQA online-softmax attention (LM substrate)

``ops`` holds the jit'd public wrappers, ``ref`` the pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
from .ops import (  # noqa: F401
    flash_attention,
    mu_update_h,
    mu_update_w,
    pairwise_sq_dists,
    silhouette_dist_sums,
    silhouette_dist_sums_batched,
)
