"""Blocked pairwise squared-L2 distance Pallas kernel (TPU target).

T_scorer hot spot: silhouette and Davies-Bouldin both need all-pairs
distances D2[i,j] = ||x_i||^2 + ||y_j||^2 - 2 x_i.y_j. The GPU reference
builds D2 from a GEMM plus two broadcast passes; the TPU version fuses the
norm computation and the bias into the GEMM epilogue so each (bn, bm)
output tile is produced in one VMEM-resident pass — one HBM write of D2,
zero intermediate reads.

Feature dim d is padded to the 128-lane width by ops.py (zero padding is
exact for distances). Grid reduces over d-tiles for large d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_kernel(x_ref, y_ref, out_ref, acc_ref, *, n_steps: int):
    """Grid = (n_tiles, m_tiles, d_steps): acc += -2 X_blk Y_blk^T, plus
    per-tile row norms folded in on the final step."""
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, bd)
    y = y_ref[...].astype(jnp.float32)  # (bm, bd)
    acc_ref[...] += (
        jax.lax.dot_general(
            x, y, dimension_numbers=(((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * -2.0
        + jnp.sum(x * x, axis=1)[:, None]
        + jnp.sum(y * y, axis=1)[None, :]
    )

    @pl.when(step == n_steps - 1)
    def _finalize():
        out_ref[...] = jnp.maximum(acc_ref[...], 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bd", "interpret"))
def pairwise_sq_dists(
    x: jax.Array,  # (n, d)
    y: jax.Array,  # (m, d)
    bn: int = 128,
    bm: int = 128,
    bd: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, d = x.shape
    m = y.shape[0]
    assert n % bn == 0 and m % bm == 0 and d % bd == 0, (n, m, d)
    n_steps = d // bd
    grid = (n // bn, m // bm, n_steps)
    return pl.pallas_call(
        functools.partial(_pairwise_kernel, n_steps=n_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, s: (i, s)),
            pl.BlockSpec((bm, bd), lambda i, j, s: (j, s)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        scratch_shapes=[_vmem((bn, bm))],
        interpret=interpret,
    )(x, y)


def _batched_pairwise_kernel(x_ref, y_ref, out_ref, acc_ref, *, n_steps: int):
    """Grid = (batch, n_tiles, m_tiles, d_steps) — same tile walk as the 2-D
    kernel with a leading batch-lane dimension, so one launch covers every
    lane of a padded wavefront (e.g. the pooled W columns of each k in a
    batched NMFk wave)."""
    step = pl.program_id(3)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)  # (bn, bd)
    y = y_ref[0].astype(jnp.float32)  # (bm, bd)
    acc_ref[...] += (
        jax.lax.dot_general(
            x, y, dimension_numbers=(((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * -2.0
        + jnp.sum(x * x, axis=1)[:, None]
        + jnp.sum(y * y, axis=1)[None, :]
    )

    @pl.when(step == n_steps - 1)
    def _finalize():
        out_ref[0] = jnp.maximum(acc_ref[...], 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bd", "interpret"))
def pairwise_sq_dists_batched(
    x: jax.Array,  # (b, n, d)
    y: jax.Array,  # (b, m, d)
    bn: int = 128,
    bm: int = 128,
    bd: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, n, d = x.shape
    m = y.shape[1]
    assert y.shape[0] == b and n % bn == 0 and m % bm == 0 and d % bd == 0, (b, n, m, d)
    n_steps = d // bd
    grid = (b, n // bn, m // bm, n_steps)
    return pl.pallas_call(
        functools.partial(_batched_pairwise_kernel, n_steps=n_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bd), lambda l, i, j, s: (l, i, s)),
            pl.BlockSpec((1, bm, bd), lambda l, i, j, s: (l, j, s)),
        ],
        out_specs=pl.BlockSpec((1, bn, bm), lambda l, i, j, s: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n, m), jnp.float32),
        scratch_shapes=[_vmem((bn, bm))],
        interpret=interpret,
    )(x, y)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
