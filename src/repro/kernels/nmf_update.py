"""Fused NMF multiplicative-update Pallas kernels (TPU target).

The paper's T_model inner loop is the Lee-Seung MU sweep. On GPU the
reference implementation leans on cuBLAS GEMMs with separate element-wise
passes; the TPU-native adaptation fuses the reduction GEMM with the
multiplicative update so the (k, m)/(n, k) numerator never round-trips HBM:

  H-update:  H <- H * (W^T V) / (G H + eps),  G = W^T W  (k×k, precomputed)
  W-update:  W <- W * (V H^T) / (W Q + eps),  Q = H H^T  (k×k, precomputed)

Tiling: the grid reduces over the long axis (n for H-update, m for
W-update) with a VMEM fp32 accumulator revisited across reduction steps;
the final reduction step applies the fused divide-multiply and writes the
updated factor tile. ops.py pads k to the 128-lane MXU width on the TPU
path (and to 8 under interpret mode, where lane alignment is moot);
zero-padded rows/columns are preserved as zeros by the update algebra.

Block shapes default to (128, 128)-aligned tiles: with k<=256 the working
set per step is bk*bm (H tile) + bn*bk (W tile) + bn*bm (V tile) + k*k,
comfortably inside the ~16 MiB v5e VMEM for 256-wide tiles in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-9


def _h_update_kernel(v_ref, w_ref, h_ref, g_ref, out_ref, acc_ref, *, n_steps: int):
    """Grid = (m_tiles, n_steps). Accumulates W_blk^T V_blk over n, then
    applies H * acc / (G H + eps) on the last reduction step."""
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (k, bn) @ (bn, bm) -> (k, bm) in fp32 on the MXU
    acc_ref[...] += jax.lax.dot_general(
        w_ref[...],
        v_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(step == n_steps - 1)
    def _finalize():
        h = h_ref[...].astype(jnp.float32)
        den = (
            jax.lax.dot_general(
                g_ref[...],
                h,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            + _EPS
        )
        out_ref[...] = (h * acc_ref[...] / den).astype(out_ref.dtype)


def _w_update_kernel(v_ref, h_ref, w_ref, q_ref, out_ref, acc_ref, *, n_steps: int):
    """Grid = (n_tiles, m_steps). Accumulates V_blk H_blk^T over m, then
    applies W * acc / (W Q + eps)."""
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (bn, bm) @ (bm, k)^T -> (bn, k)
    acc_ref[...] += jax.lax.dot_general(
        v_ref[...],
        h_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(step == n_steps - 1)
    def _finalize():
        w = w_ref[...].astype(jnp.float32)
        den = (
            jax.lax.dot_general(
                w,
                q_ref[...],
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            + _EPS
        )
        out_ref[...] = (w * acc_ref[...] / den).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def h_update(
    v: jax.Array,  # (n, m)
    w: jax.Array,  # (n, k)   k padded to lane width by ops.py
    h: jax.Array,  # (k, m)
    g: jax.Array,  # (k, k) = W^T W
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, m = v.shape
    k = w.shape[1]
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    n_steps = n // bn
    grid = (m // bm, n_steps)
    return pl.pallas_call(
        functools.partial(_h_update_kernel, n_steps=n_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm), lambda j, s: (s, j)),  # V tile walks n
            pl.BlockSpec((bn, k), lambda j, s: (s, 0)),  # W tile walks n
            pl.BlockSpec((k, bm), lambda j, s: (0, j)),  # H tile fixed per j
            pl.BlockSpec((k, k), lambda j, s: (0, 0)),  # G resident
        ],
        out_specs=pl.BlockSpec((k, bm), lambda j, s: (0, j)),
        out_shape=jax.ShapeDtypeStruct((k, m), h.dtype),
        scratch_shapes=[pltpu_vmem((k, bm))],
        interpret=interpret,
    )(v, w, h, g)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def w_update(
    v: jax.Array,  # (n, m)
    h: jax.Array,  # (k, m)
    w: jax.Array,  # (n, k)
    q: jax.Array,  # (k, k) = H H^T
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, m = v.shape
    k = h.shape[0]
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    m_steps = m // bm
    grid = (n // bn, m_steps)
    return pl.pallas_call(
        functools.partial(_w_update_kernel, n_steps=m_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, s: (i, s)),  # V tile walks m
            pl.BlockSpec((k, bm), lambda i, s: (0, s)),  # H tile walks m
            pl.BlockSpec((bn, k), lambda i, s: (i, 0)),  # W tile fixed per i
            pl.BlockSpec((k, k), lambda i, s: (0, 0)),  # Q resident
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), w.dtype),
        scratch_shapes=[pltpu_vmem((bn, k))],
        interpret=interpret,
    )(v, h, w, q)


def pltpu_vmem(shape):
    """VMEM fp32 scratch (works under interpret=True on CPU)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
