"""Jit'd public wrappers around the Pallas kernels.

Handle padding to MXU/lane alignment, dtype plumbing, and interpret-mode
fallback (this container is CPU-only; on CPU the kernels execute their
Python bodies under ``interpret=True`` — bit-identical logic, same BlockSpec
walk — while on TPU the same code lowers to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import nmf_update as _nmf
from . import pairwise_dist as _pd
from . import silhouette_sums as _ss


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _lane_mult(interpret: bool) -> int:
    """Rank/lane padding multiple: the 128-lane MXU width on the real TPU
    path, 8 under interpret mode where lane alignment buys nothing and
    128-padding tiny-k problems would only waste interpreter time."""
    return 8 if interpret else 128


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# -----------------------------------------------------------------------------
# NMF multiplicative updates
# -----------------------------------------------------------------------------
def mu_update_h(v: jax.Array, w: jax.Array, h: jax.Array, interpret: bool | None = None) -> jax.Array:
    """Fused H <- H * (W^T V)/(W^T W H + eps); pads (n, m) to tiles and k to
    the lane width (128 on TPU, 8 under interpret — see ``_lane_mult``)."""
    interpret = _interpret_default() if interpret is None else interpret
    n, m = v.shape
    k = w.shape[1]
    bn = 128 if n % 128 == 0 else 8
    bm = 128 if m % 128 == 0 else 8
    bk = _lane_mult(interpret)
    vp = _pad_to(_pad_to(v, 0, bn), 1, bm)
    wp = _pad_to(_pad_to(w, 0, bn), 1, bk)
    hp = _pad_to(_pad_to(h, 0, bk), 1, bm)
    g = wp.T @ wp  # (kp, kp) — cheap, fp32
    out = _nmf.h_update(vp, wp, hp, g, bm=bm, bn=bn, interpret=interpret)
    return out[:k, :m].astype(h.dtype)


def mu_update_w(v: jax.Array, w: jax.Array, h: jax.Array, interpret: bool | None = None) -> jax.Array:
    """Fused W <- W * (V H^T)/(W H H^T + eps); k padded like ``mu_update_h``."""
    interpret = _interpret_default() if interpret is None else interpret
    n, m = v.shape
    k = w.shape[1]
    bn = 128 if n % 128 == 0 else 8
    bm = 128 if m % 128 == 0 else 8
    bk = _lane_mult(interpret)
    vp = _pad_to(_pad_to(v, 0, bn), 1, bm)
    wp = _pad_to(_pad_to(w, 0, bn), 1, bk)
    hp = _pad_to(_pad_to(h, 0, bk), 1, bm)
    q = hp @ hp.T
    out = _nmf.w_update(vp, hp, wp, q, bm=bm, bn=bn, interpret=interpret)
    return out[:n, :k].astype(w.dtype)


# -----------------------------------------------------------------------------
# Pairwise distances
# -----------------------------------------------------------------------------
def pairwise_sq_dists(x: jax.Array, y: jax.Array | None = None, interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    y = x if y is None else y
    n, d = x.shape
    m = y.shape[0]
    bn = 128 if n % 128 == 0 else 8
    bm = 128 if m % 128 == 0 else 8
    bd = 128 if d % 128 == 0 else 8
    xp = _pad_to(_pad_to(x, 0, bn), 1, bd)
    yp = _pad_to(_pad_to(y, 0, bm), 1, bd)
    out = _pd.pairwise_sq_dists(xp, yp, bn=bn, bm=bm, bd=bd, interpret=interpret)
    return out[:n, :m]


def pairwise_sq_dists_batched(
    x: jax.Array, y: jax.Array | None = None, interpret: bool | None = None
) -> jax.Array:
    """Leading-axis batched pairwise distances: x (b, n, d), y (b, m, d).

    One kernel launch covers all b lanes — the entry point batched scorers
    use instead of vmapping the 2-D kernel. Zero padding of n/m/d to tile
    multiples is exact for distances; callers slice the result.
    """
    interpret = _interpret_default() if interpret is None else interpret
    y = x if y is None else y
    _, n, d = x.shape
    m = y.shape[1]
    bn = 128 if n % 128 == 0 else 8
    bm = 128 if m % 128 == 0 else 8
    bd = 128 if d % 128 == 0 else 8
    xp = _pad_to(_pad_to(x, 1, bn), 2, bd)
    yp = _pad_to(_pad_to(y, 1, bm), 2, bd)
    out = _pd.pairwise_sq_dists_batched(xp, yp, bn=bn, bm=bm, bd=bd, interpret=interpret)
    return out[:, :n, :m]


# -----------------------------------------------------------------------------
# Streaming silhouette dist-sums (fused distance + cluster reduction)
# -----------------------------------------------------------------------------
def silhouette_dist_sums(
    x: jax.Array,
    onehot: jax.Array,
    y: jax.Array | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """(n, k) cluster distance sums ``sqrt(pairwise(x, y)) @ onehot`` without
    materializing the (n, m) distance matrix.

    x (n, d), y (m, d) (default x), onehot (m, k) with zero rows for
    masked/padded points. Zero-padding m is exact because padded one-hot
    rows are zero (their distances contract to nothing); zero-padding d is
    exact for distances; padded n rows and k columns are sliced off.
    """
    interpret = _interpret_default() if interpret is None else interpret
    y = x if y is None else y
    n, d = x.shape
    m, k = onehot.shape
    bn = 128 if n % 128 == 0 else 8
    bm = 128 if m % 128 == 0 else 8
    bd = 128 if d % 128 == 0 else 8
    xp = _pad_to(_pad_to(x, 0, bn), 1, bd)
    yp = _pad_to(_pad_to(y, 0, bm), 1, bd)
    gp = _pad_to(_pad_to(onehot, 0, bm), 1, _lane_mult(interpret))
    out = _ss.silhouette_dist_sums(xp, yp, gp, bn=bn, bm=bm, bd=bd, interpret=interpret)
    return out[:n, :k]


def silhouette_dist_sums_batched(
    x: jax.Array,
    onehot: jax.Array,
    y: jax.Array | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Leading-axis batched streaming dist-sums: x (b, n, d), onehot (b, m, k).

    One launch streams all b wavefront lanes; the (b, n, m) distance block
    the dense batched path would write to HBM never exists.
    """
    interpret = _interpret_default() if interpret is None else interpret
    y = x if y is None else y
    _, n, d = x.shape
    _, m, k = onehot.shape
    bn = 128 if n % 128 == 0 else 8
    bm = 128 if m % 128 == 0 else 8
    bd = 128 if d % 128 == 0 else 8
    xp = _pad_to(_pad_to(x, 1, bn), 2, bd)
    yp = _pad_to(_pad_to(y, 1, bm), 2, bd)
    gp = _pad_to(_pad_to(onehot, 1, bm), 2, _lane_mult(interpret))
    out = _ss.silhouette_dist_sums_batched(xp, yp, gp, bn=bn, bm=bm, bd=bd, interpret=interpret)
    return out[:, :n, :k]


# -----------------------------------------------------------------------------
# Flash attention
# -----------------------------------------------------------------------------
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal/windowed GQA flash attention; pads L to tiles and D to lanes."""
    interpret = _interpret_default() if interpret is None else interpret
    b, hq, lq, d = q.shape
    lk = k.shape[2]
    scale = float(scale if scale is not None else d ** -0.5)
    bq = 128 if lq % 128 == 0 else 8
    bk = 128 if lk % 128 == 0 else 8
    dp = 128 if d % 128 == 0 else 8
    qp = _pad_to(_pad_to(q, 2, bq), 3, dp)
    kp = _pad_to(_pad_to(k, 2, bk), 3, dp)
    vp = _pad_to(_pad_to(v, 2, bk), 3, dp)
    # Padded kv rows sit at indices >= lk; with causal masking and lq == lk
    # no real query row can attend them (k_idx > q_idx), so zero-padding is
    # exact. Non-causal use requires pre-aligned lengths.
    if kp.shape[2] != lk:
        assert causal and lq == lk, "kv-length padding requires causal attention with lq == lk"
    out = _fa.flash_attention(
        qp, kp, vp, causal=causal, window=window, scale=scale, bq=bq, bk=bk, interpret=interpret
    )
    return out[:, :, :lq, :d]
