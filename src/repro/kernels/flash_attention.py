"""Causal GQA flash attention Pallas kernel (TPU target).

The LM substrate's dominant compute. Online-softmax tiling (Dao et al.)
re-thought for TPU: (bq × d) query tiles resident in VMEM, kv tiles
streamed HBM→VMEM along the innermost grid axis, fp32 running (m, l, acc)
in VMEM scratch, output written once per q tile. MXU-aligned block shapes
(bq, bk multiples of 128 at the target; interpret mode relaxes this).

Supports:
  * causal masking,
  * GQA: kv-head blocks are index-mapped as ``h_q // group`` so grouped
    query heads stream the same kv tiles (no kv replication in HBM),
  * sliding-window attention (h2o-danube / Jamba-style local attention):
    ``window`` keys — with causal+window, fully-masked kv tiles are
    skipped entirely, making train-time attention O(L·W).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_BIG = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    out_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    bq: int,
    bk: int,
    kv_steps: int,
    causal: bool,
    window: int | None,
):
    qi = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = s * bk

    # tile-level skip: with causal (and optional window) some kv tiles are
    # entirely masked — do no work for them.
    tile_live = jnp.asarray(True)
    if causal:
        tile_live = k_start <= q_start + bq - 1
    if window is not None:
        tile_live = jnp.logical_and(tile_live, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(tile_live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        st = (
            jax.lax.dot_general(
                q, k, dimension_numbers=(((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )  # (bq, bk)
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_idx <= q_idx)
        if window is not None:
            mask = jnp.logical_and(mask, k_idx > q_idx - window)
        st = jnp.where(mask, st, _NEG_BIG)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(st, axis=1, keepdims=True))
        p = jnp.exp(st - m_new)  # (bq, bk)
        corr = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(s == kv_steps - 1)
    def _finalize():
        out_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "scale", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Lq, D)
    k: jax.Array,  # (B, Hk, Lk, D)
    v: jax.Array,  # (B, Hk, Lk, D)
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hk, lk, _ = k.shape
    assert hq % hk == 0, (hq, hk)
    group = hq // hk
    assert lq % bq == 0 and lk % bk == 0, (lq, lk, bq, bk)
    scale = float(scale if scale is not None else d ** -0.5)
    kv_steps = lk // bk
    grid = (b, hq, lq // bq, kv_steps)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        bq=bq,
        bk=bk,
        kv_steps=kv_steps,
        causal=causal,
        window=window,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, h, qi, s: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, qi, s, g=group: (bi, h // g, s, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, qi, s, g=group: (bi, h // g, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, h, qi, s: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[_vmem((bq, 1)), _vmem((bq, 1)), _vmem((bq, d))],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
