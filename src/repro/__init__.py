"""repro — Binary Bleed (LANL, CS.DC 2024) as a production JAX framework.

Public API:
    repro.core           — the paper's algorithms (search, schedule, score)
    repro.factorization  — NMF/NMFk/K-Means/RESCAL (+ distributed)
    repro.models         — the 10 assigned LM architectures
    repro.launch         — mesh / dryrun / train / serve / ksearch drivers
"""
__version__ = "1.0.0"
