"""Distributed Binary Bleed k-search driver — the paper end-to-end.

Composes the whole system: the mesh is carved into R sub-meshes
("resources" in the paper's terms); Binary Bleed chunks K over them
(Algorithm 2 + pre-order sort) and each resource evaluates its k values —
each evaluation itself a *distributed* NMFk fit over that resource's
devices (pyDNMFk mode). Pruning broadcasts flow through the coordinator
(in-process for threads, file-based across hosts), and the journal makes
the search restartable mid-flight.

On this CPU container the sub-meshes are 1-device and resources are
threads — the control plane is identical to the 512-chip layout; swap
``make_submeshes`` for pod slices on real hardware.

  PYTHONPATH=src python -m repro.launch.ksearch --k-max 16 --k-true 5 \
      --resources 4 --early-stop

``--executor sharded`` replaces threads with the mesh-sharded wavefront
plane: one jit'd dispatch fits a whole frontier, k-lanes split over the
mesh's ``lane`` axis and (``--data-shards > 1``) V's rows over ``data``.
Validate on CPU with 8 virtual devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.ksearch --executor sharded --k-max 32

``--executor elastic`` replaces fixed-iteration waves with continuous
batching over fit-chunks: lanes retire as soon as their fit converges
(``--tol``), freed slots refill from the worklist mid-stream, refilled ks
warm-start from completed neighbors (``--warm-start``), and §III-D prunes
evict in-flight ks between chunks. Shard-maps like ``sharded`` when
``--lanes`` / ``--data-shards`` are given:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.ksearch --executor elastic --k-max 32
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import (
    ElasticWavefrontScheduler,
    FileCoordinator,
    InProcessCoordinator,
    LaneRefillPolicy,
    SearchSpace,
    ThreadPoolScheduler,
    WavefrontScheduler,
    enable_persistent_cache,
    make_space,
)
from repro.factorization.distributed import distributed_nmf, make_local_mesh
from repro.factorization.nmfk import nmfk_score
from repro.factorization.planes import NMFkBatchPlane, NMFkElasticPlane
from repro.factorization.synthetic import nmf_data
from repro.launch.mesh import SubmeshPool, make_wave_mesh
from repro.obs import NULL_TRACER, Metrics, Tracer, use_metrics, use_tracer


def make_submeshes(num_resources: int):
    """Carve jax.devices() into `num_resources` sub-meshes (round-robin).

    On a pod this is `mesh.devices.reshape(R, -1)` slices; on CPU every
    resource gets the single device (threads share it)."""
    devs = jax.devices()
    if len(devs) >= num_resources:
        per = len(devs) // num_resources
        return [make_local_mesh(per) for _ in range(num_resources)]
    return [make_local_mesh(len(devs)) for _ in range(num_resources)]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=96)
    ap.add_argument("--m", type=int, default=104)
    ap.add_argument("--k-true", type=int, default=5)
    ap.add_argument("--k-min", type=int, default=2)
    ap.add_argument("--k-max", type=int, default=16)
    ap.add_argument("--resources", type=int, default=4)
    ap.add_argument("--threshold", type=float, default=0.9)
    ap.add_argument("--early-stop", action="store_true")
    ap.add_argument("--stop-threshold", type=float, default=0.1)
    ap.add_argument("--order", default="pre", choices=["pre", "in", "post"])
    ap.add_argument("--n-perturbs", type=int, default=4)
    ap.add_argument("--nmf-iters", type=int, default=120)
    ap.add_argument("--journal", default=None, help="dir for FileCoordinator (restartable)")
    ap.add_argument("--distributed-fit", action="store_true",
                    help="run each NMF fit via shard_map over the resource's sub-mesh")
    ap.add_argument("--executor", default="threads",
                    choices=["threads", "batched", "sharded", "elastic"],
                    help="threads: one fit per k per worker; batched: wavefront "
                    "frontiers as one padded vmapped NMFk fit per wave; sharded: "
                    "wavefront frontiers shard_map'd over a (lane, data) mesh — "
                    "parallel-over-k across lanes, distributed-within-k when "
                    "--data-shards > 1; elastic: continuous batching over "
                    "fit-chunks — lanes retire on per-fit convergence (--tol), "
                    "freed slots refill from the worklist, new ks warm-start "
                    "from neighbors (shard-maps like sharded when --lanes or "
                    "--data-shards is given)")
    ap.add_argument("--max-wave", type=int, default=None,
                    help="cap ks per batched dispatch (batched/sharded executors)")
    ap.add_argument("--lanes", type=int, default=None,
                    help="lane-axis size of the sharded mesh (default: all "
                    "visible devices / --data-shards)")
    ap.add_argument("--data-shards", type=int, default=1,
                    help="data-axis size of the sharded mesh: each lane's NMF "
                    "fit row-shards V over this many devices (pyDNMFk mode)")
    ap.add_argument("--comm", default="sync", choices=["sync", "pipelined"],
                    help="collective schedule of the data-sharded fits: sync "
                    "blocks each MU sweep on the Gram all-reduces; pipelined "
                    "decomposes them into psum_scatter + ring all-gather and "
                    "overlaps the in-flight reduction with the local W-update "
                    "(one-sweep-stale H, final sync sweep). Only meaningful "
                    "with --executor sharded and --data-shards > 1")
    ap.add_argument("--tol", type=float, default=1e-3,
                    help="elastic convergence gate: a lane retires when its "
                    "rel_error improved by less than this over the last chunk "
                    "(chunk-size dependent; <= 0 disables the gate — every "
                    "lane then runs exactly --nmf-iters sweeps, reproducing "
                    "the batched executor draw-for-draw)")
    ap.add_argument("--fit-chunk", type=int, default=25,
                    help="elastic chunk size: MU sweeps per dispatch between "
                    "convergence checks / refills / abort polls")
    ap.add_argument("--warm-start", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="seed refilled elastic lanes from the nearest "
                    "completed k's W (column pad/truncate + re-normalize); "
                    "--no-warm-start cold-starts every lane")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent jit compile cache dir: the handful of "
                    "bucketed (batch, k_pad) shapes compile once across runs")
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="write a search trace: Chrome-trace/Perfetto JSON "
                    "(open at ui.perfetto.dev), or JSONL if OUT ends in .jsonl")
    ap.add_argument("--metrics", default=None, metavar="OUT",
                    help="write the metrics summary JSON (counters/gauges/"
                    "histograms + pruning-efficiency block)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.compile_cache:
        # before the first jit dispatch: earlier compiles are not retro-cached
        enable_persistent_cache(args.compile_cache)

    key = jax.random.PRNGKey(0)
    v, _, _ = nmf_data(key, n=args.n, m=args.m, k_true=args.k_true)
    pool = SubmeshPool(make_submeshes(args.resources))

    def evaluate(k: int, should_abort=None) -> float:
        sub = jax.random.fold_in(key, k)
        if args.distributed_fit:
            # paper's distributed mode: the fit itself is sharded over this
            # *worker's* leased sub-mesh (a worker-identity resource — keying
            # by k collides concurrent workers onto one device group);
            # scoring still ensembles perturbations (cheap at this scale).
            res = distributed_nmf(v, int(k), sub, pool.acquire(), iters=args.nmf_iters)
            del res
        sc = nmfk_score(v, int(k), sub, n_perturbs=args.n_perturbs, nmf_iters=args.nmf_iters)
        return float(sc.min_silhouette)

    space = make_space(
        (args.k_min, args.k_max),
        args.threshold,
        args.stop_threshold if args.early_stop else None,
    )

    # telemetry: a real tracer only when requested (NullTracer otherwise —
    # allocation-free hot path); metrics are always on but scoped to this
    # run so summary()'s visit_fraction reflects exactly this search.
    tracer = Tracer() if args.trace else NULL_TRACER
    metrics = Metrics()
    with use_tracer(tracer), use_metrics(metrics):
        result, dt, extra = _run_search(args, ap, space, v, key, evaluate)

    out = _emit(args, result, dt, extra, tracer, metrics)
    return out


def _run_search(args, ap, space, v, key, evaluate):
    if args.executor == "elastic":
        if not args.quiet:
            for flag, used in (("--journal", args.journal),
                               ("--distributed-fit", args.distributed_fit),
                               ("--resources", args.resources != ap.get_default("resources")),
                               ("--max-wave", args.max_wave is not None)):
                if used:
                    print(f"note: {flag} is ignored by the elastic executor")
        mesh = None
        if args.lanes is not None or args.data_shards > 1:
            mesh = make_wave_mesh(lanes=args.lanes, data=args.data_shards)
        plane = NMFkElasticPlane(
            v, key, n_perturbs=args.n_perturbs, nmf_iters=args.nmf_iters,
            k_pad=args.k_max, tol=args.tol, chunk=args.fit_chunk,
            warm_start=args.warm_start, mesh=mesh, comm=args.comm,
        )
        sched = ElasticWavefrontScheduler(space, refill=LaneRefillPolicy(order=args.order))
        t0 = time.time()
        result = sched.run(plane)
        dt = time.time() - t0
        extra = {
            "ticks": sched.n_ticks,
            "compiled_shapes": sorted(plane.shapes_compiled),
            "tol": args.tol,
            "fit_chunk": args.fit_chunk,
            "warm_start": args.warm_start,
            "sweeps_run": plane.sweeps_run,
            "sweeps_saved": plane.sweeps_saved,
            "sweeps_fixed_total": plane.sweeps_fixed_total,
            "warm_start_hits": plane.warm_cache.hits,
            "lane_occupancy": plane.last_lane_occupancy,
            "lane_utilization_last": plane.last_lane_utilization,
        }
        if mesh is not None:
            extra["mesh"] = {"lanes": plane.lane_count, "data": plane.data_count}
            extra["comm"] = args.comm
        return result, dt, extra
    if args.executor in ("batched", "sharded"):
        if not args.quiet:
            ignored = (
                ("--journal", args.journal),
                ("--distributed-fit", args.distributed_fit),
                ("--order", args.order != "pre"),
                ("--resources", args.resources != ap.get_default("resources")),
            )
            for flag, used in ignored:
                if used:
                    print(f"note: {flag} is ignored by the {args.executor} executor")
        mesh = None
        if args.executor == "sharded":
            mesh = make_wave_mesh(lanes=args.lanes, data=args.data_shards)
        elif args.comm != "sync" and not args.quiet:
            print(f"note: --comm is ignored by the {args.executor} executor")
        plane = NMFkBatchPlane(
            v, key, n_perturbs=args.n_perturbs, nmf_iters=args.nmf_iters,
            k_pad=args.k_max, mesh=mesh, comm=args.comm,
        )
        if (mesh is not None and args.comm == "pipelined"
                and plane.data_count <= 1 and not args.quiet):
            print("note: --comm pipelined is a no-op without --data-shards > 1")
        sched = WavefrontScheduler(space, max_wave=args.max_wave)
        t0 = time.time()
        result = sched.run(plane)
        dt = time.time() - t0
        extra = {"waves": sched.n_dispatches, "compiled_shapes": sorted(plane.shapes_compiled)}
        if mesh is not None:
            extra["mesh"] = {"lanes": plane.lane_count, "data": plane.data_count}
            extra["lane_utilization_last"] = plane.last_lane_utilization
            extra["comm"] = args.comm
            if args.comm == "pipelined" and plane.data_count > 1:
                from repro.obs import get_metrics

                extra["overlap_fraction"] = get_metrics().gauge("overlap_fraction")
    else:
        visited: set[int] = set()
        if args.journal:
            coord = FileCoordinator(args.journal)
            bounds, visited = coord.replay(space.selects, space.stops)
            if visited and not args.quiet:
                print(f"restart: {len(visited)} k already journaled, bounds {bounds}")
        else:
            coord = InProcessCoordinator()
        sched = ThreadPoolScheduler(space, args.resources, order=args.order, coordinator=coord)
        t0 = time.time()
        result = sched.run(evaluate, skip=visited)
        dt = time.time() - t0
        extra = {"resources": args.resources}
    return result, dt, extra


def _emit(args, result, dt, extra, tracer, metrics) -> dict:
    out = {
        "k_optimal": result.k_optimal,
        "k_true": args.k_true,
        "visited": sorted(result.visited_ks),
        "n_visited": result.n_visited,
        "n_candidates": result.n_candidates,
        "visit_fraction": round(result.visit_fraction, 3),
        "seconds": round(dt, 2),
        "executor": args.executor,
        **extra,
    }
    if args.trace:
        if args.trace.endswith(".jsonl"):
            n_ev = tracer.export_jsonl(args.trace)
        else:
            n_ev = tracer.export_perfetto(args.trace)
        out["trace"] = {"path": args.trace, "events": n_ev}
    if args.metrics:
        summary = metrics.summary()
        payload = {
            "summary": summary,
            "result": {
                "k_optimal": result.k_optimal,
                "n_visited": result.n_visited,
                "n_candidates": result.n_candidates,
                "visit_fraction": result.visit_fraction,
            },
            "seconds": dt,
            "executor": args.executor,
        }
        with open(args.metrics, "w") as f:
            json.dump(payload, f, indent=1)
        out["metrics"] = {"path": args.metrics}
        sf = summary["search"]["visit_fraction"]
        if sf is not None and abs(sf - result.visit_fraction) > 1e-9 and not args.quiet:
            print(f"warning: metrics visit_fraction {sf:.3f} != "
                  f"result {result.visit_fraction:.3f}")
    if not args.quiet:
        print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
