"""Production mesh construction + axis environments + FSDP spec widening.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; batch shards over
(pod, data), parameters/experts/heads over model, FSDP over data.

K-search meshes: ``make_wave_mesh`` carves the visible devices into the
2-D ``(lane, data)`` mesh the sharded wavefront planes consume, and
``SubmeshPool`` leases per-worker submeshes to the threaded distributed-fit
executor (each worker keeps ONE submesh for its lifetime — submeshes are
a worker-identity resource, not a function of the k being evaluated).

Functions (not module constants) so importing never touches jax device
state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import threading
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.layers import Axes

PyTree = Any


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_wave_mesh(
    lanes: int | None = None, data: int = 1, devices: Sequence[Any] | None = None
) -> Mesh:
    """2-D ``(lane, data)`` mesh for the sharded wavefront planes.

    ``lanes`` parallel k-fits, each distributed over ``data`` devices
    (pyDNMFk psum structure) — lanes × data devices total. With
    ``lanes=None`` every remaining device becomes a lane
    (``len(devices) // data``). Raises if the device count doesn't factor.
    """
    devs = list(devices) if devices is not None else jax.devices()
    if data < 1:
        raise ValueError(f"data must be >= 1, got {data}")
    if lanes is None:
        if len(devs) % data:
            raise ValueError(f"{len(devs)} devices do not split into data={data} shards")
        lanes = len(devs) // data
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    need = lanes * data
    if need > len(devs):
        raise ValueError(f"mesh ({lanes} lanes x {data} data) needs {need} devices, "
                         f"have {len(devs)}")
    return jax.make_mesh((lanes, data), ("lane", "data"), devices=devs[:need])


class SubmeshPool:
    """Lease one submesh per *worker* for the threaded distributed-fit path.

    The executor's workers are threads that each run one k-evaluation at a
    time on a dedicated device group; the evaluate closure only sees the k,
    so the pool keys the lease on ``threading.get_ident()``. First touch
    assigns the next free submesh round-robin; every later call from the
    same worker returns the same submesh. (Keying on k instead — e.g.
    ``submeshes[k % n]`` — lands two concurrent workers on the same device
    group whenever their ks collide mod n, serializing the fits the
    submeshes exist to parallelize.)
    """

    def __init__(self, submeshes: Sequence[Mesh]):
        if not submeshes:
            raise ValueError("SubmeshPool needs at least one submesh")
        self.submeshes = list(submeshes)
        self._lock = threading.Lock()
        self._assign: dict[int, Mesh] = {}

    def acquire(self) -> Mesh:
        """The calling worker's submesh (assigned on first touch)."""
        ident = threading.get_ident()
        with self._lock:
            mesh = self._assign.get(ident)
            if mesh is None:
                mesh = self.submeshes[len(self._assign) % len(self.submeshes)]
                self._assign[ident] = mesh
            return mesh

    def assignments(self) -> dict[int, int]:
        """thread ident -> submesh index (introspection for tests/traces)."""
        with self._lock:
            index = {id(m): i for i, m in enumerate(self.submeshes)}
            return {t: index[id(m)] for t, m in self._assign.items()}


def make_axes(mesh: Mesh, global_batch: int | None = None) -> Axes:
    """Axis environment for a mesh; drops batch sharding when the global
    batch can't shard evenly (long_500k's batch=1)."""
    names = mesh.axis_names
    batch_axes = tuple(n for n in ("pod", "data") if n in names)
    if global_batch is not None:
        dp = 1
        for n in batch_axes:
            dp *= mesh.shape[n]
        if global_batch % dp != 0:
            batch_axes = ()
    return Axes(batch=batch_axes, model="model", model_size=mesh.shape["model"])


def dp_size(mesh: Mesh) -> int:
    dp = 1
    for n in ("pod", "data"):
        if n in mesh.axis_names:
            dp *= mesh.shape[n]
    return dp


def apply_fsdp(
    specs: PyTree, shapes: PyTree, fsdp_axis: str = "data", fsdp_size: int = 16,
    min_elems: int = 1 << 22,
) -> PyTree:
    """Widen param specs with FSDP sharding over `fsdp_axis`.

    For every leaf >= min_elems whose spec has a None entry on a dim
    divisible by fsdp_size, shard that dim over the fsdp axis. This is the
    MaxText-style fsdp+tensor hybrid: without it, llama3-405b's bf16 params
    are 50 GB/device (model-axis only); with it they are 3.2 GB/device.
    """

    def widen(spec: P, shaped) -> P:
        shape = shaped.shape
        if len(shape) != len(spec):
            # stacked-segment leading dim etc. — pad spec view
            return spec
        n = 1
        for s in shape:
            n *= s
        if n < min_elems:
            return spec
        entries = list(spec)
        # prefer widening the largest eligible dim (least padding waste);
        # never shard the leading layer-stack dim of scanned params (>=3D)
        start = 1 if len(shape) >= 3 else 0
        order = sorted(range(start, len(shape)), key=lambda i: -shape[i])
        for i in order:
            if entries[i] is None and shape[i] % fsdp_size == 0:
                entries[i] = fsdp_axis
                return P(*entries)
        return spec

    return jax.tree.map(widen, specs, shapes, is_leaf=lambda s: isinstance(s, P))


def named(mesh: Mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda s: isinstance(s, P)
    )
