"""End-to-end training driver.

CPU-scale by default (reduced config) so the full loop — data pipeline,
jit'd train_step with grad accumulation, async checkpointing, restart —
is actually exercised; pass --full only on real hardware.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --steps 50 \
      --reduced --ckpt /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticTokenSource
from repro.models.layers import Axes
from repro.models.transformer import Model
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compression", default="none", choices=["none", "bf16", "int8"])
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    model = Model(cfg, Axes(batch=("data",), model="model", model_size=1),
                  remat="none", dtype=jnp.float32)
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=max(args.steps, 10)),
        microbatches=args.microbatches,
        compression=args.compression,
    )
    step_fn = jax.jit(make_train_step(model, tcfg))

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = init_opt_state(params, tcfg.opt)
    start_step = 0
    saver = None
    if args.ckpt:
        saver = ckpt.AsyncCheckpointer(args.ckpt)
        if args.resume and ckpt.latest_step(args.ckpt) is not None:
            (params, opt), start_step = ckpt.restore(args.ckpt, (params, opt))
            if not args.quiet:
                print(f"resumed from step {start_step}")

    src = SyntheticTokenSource(cfg, shape, DataConfig(seed=0))
    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if not args.quiet and (step % 5 == 0 or step == args.steps - 1):
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e}")
        if saver and (step + 1) % args.ckpt_every == 0:
            saver.submit(step + 1, (params, opt))
    if saver:
        saver.submit(args.steps, (params, opt))
        saver.close()
    dt = time.time() - t0
    if not args.quiet:
        print(f"{args.steps - start_step} steps in {dt:.1f}s; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"losses": losses, "params": params, "final_loss": losses[-1] if losses else np.nan}


if __name__ == "__main__":
    main()
