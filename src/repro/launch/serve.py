"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models.layers import Axes
from repro.models.transformer import Model
from repro.serve.decode import generate


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = Model(cfg, Axes(batch=("data",), model="model", model_size=1),
                  remat="none", dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    extra = None
    if cfg.input_mode == "embeddings":
        extra = {"embeds": 0.02 * jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32)}
    t0 = time.time()
    out = generate(model, params, prompt, steps=args.tokens,
                   temperature=args.temperature, batch_extra=extra)
    dt = time.time() - t0
    if not args.quiet:
        print(f"generated {args.batch}x{args.tokens} tokens in {dt:.2f}s")
        print("sample:", out[0].tolist())
    return {"tokens": out, "seconds": dt}


if __name__ == "__main__":
    main()
