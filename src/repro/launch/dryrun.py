import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any jax import (jax locks the
# device count on first init). That also forbids `from __future__` here.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
  * build the production mesh (16×16 or 2×16×16),
  * build the model + sharding specs (TP over 'model', FSDP over 'data',
    batch over ('pod','data')),
  * jit(step).lower(<ShapeDtypeStructs>).compile()  — no allocation,
  * record memory_analysis, cost_analysis (FLOPs/bytes), and the
    collective census parsed from the optimized HLO (op × shape × bytes,
    scan trip counts folded in via known_trip_count),
  * append one JSON record to results/dryrun/<cell>.json (resumable).

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, registry, shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import apply_fsdp, dp_size, make_axes, make_production_mesh, named
from repro.models.transformer import Model
from repro.serve.decode import make_serve_step
from repro.train.optimizer import init_opt_state, opt_state_specs
from repro.train.train_step import auto_train_config, batch_specs, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\(.*?\)|\S+)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# computation header: `%name (params...) -> result {` — params may nest parens
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-$]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-$]+)\s*=\s*"
    r"((?:bf16|f64|f32|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[[0-9,]*\])"
)
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _bytes_of(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _shape_bytes_between(line: str, start: int, end: int) -> int:
    """Sum bytes of every typed shape in line[start:end] (tuple-aware)."""
    return sum(_bytes_of(dt, dims) for dt, dims in _SHAPE_RE.findall(line[start:end]))


# operand may carry an inline type prefix (`dot(f32[16,16]{1,0} %lhs, ...)`,
# newer XLA text) or not (`dot(%lhs, ...)`)
_DOT_LINE_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?[\w.\-$]+\s*=\s*"
    r"(bf16|f64|f32|f16|s64|u64|s32|u32|s16|u16|s8|u8)\[([0-9,]*)\]\S*\s+dot\("
    r"(?:[a-z0-9]+\[[0-9,]*\]\S*\s+)?%?([\w.\-$]+),"
)
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-$]+)")
_SKIP_OPS = (
    " parameter(", " constant(", " tuple(", " get-tuple-element(", " bitcast(",
    " after-all(", " partition-id(", " iota(",
)


def parse_hlo(hlo_text: str) -> dict[str, Any]:
    """Post-SPMD HLO census with loop trip counts folded in. Per device:

      * collective ops: count + payload bytes (output-shape convention),
      * dot FLOPs: 2 * prod(result dims) * prod(lhs contracting dims),
        resolving lhs shapes through a per-computation symbol table,
      * HBM traffic estimate: result bytes of top-level (non-fusion-body)
        instructions — fusion internals are VMEM/register traffic.

    Trip counts come from `known_trip_count` backend configs when present,
    else from the s32 constant in the while condition (jax counted scans).
    """
    lines_all = hlo_text.splitlines()
    comps: dict[str, list[str]] = {}
    order: list[str] = []
    cur = None
    entry = None
    for line in lines_all:
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            order.append(cur)
            if line.startswith("ENTRY"):
                entry = cur
        elif cur is not None:
            comps[cur].append(line)
    if entry is None:
        entry = order[-1] if order else None

    # computations that are fusion bodies / reducers (internal traffic only)
    internal: set[str] = set()
    for name, lines in comps.items():
        for line in lines:
            if " fusion(" in line or " reduce(" in line or " reduce-window(" in line \
               or " scatter(" in line or " sort(" in line or " select-and-scatter(" in line:
                for ref in _CALLS_RE.findall(line):
                    internal.add(ref)

    raw_coll: dict[str, dict[str, tuple[int, int]]] = {}
    raw_flops: dict[str, int] = {}
    raw_traffic: dict[str, int] = {}
    while_edges: dict[str, list[tuple[str, str, int]]] = {n: [] for n in comps}
    call_edges: dict[str, list[str]] = {n: [] for n in comps}
    cond_consts: dict[str, int] = {}

    for name, lines in comps.items():
        # symbol table: instruction -> (dtype, dims) for dot operand lookup
        sym: dict[str, tuple[str, list[int]]] = {}
        for line in lines:
            im = _INSTR_RE.match(line)
            if im:
                dt_dims = _SHAPE_RE.match(im.group(2))
                if dt_dims:
                    sym[im.group(1)] = (
                        dt_dims.group(1),
                        [int(d) for d in dt_dims.group(2).split(",") if d],
                    )
        consts = [int(c) for c in _S32_CONST_RE.findall("\n".join(lines))]
        if consts:
            cond_consts[name] = max(consts)
        by_op: dict[str, tuple[int, int]] = {}
        flops = 0
        traffic = 0
        fusion_body = name in internal
        for line in lines:
            cm = _COLL_RE.search(line)
            if cm and "-done" not in line[: cm.end()]:
                op = cm.group(1)
                eq = line.find("=")
                b = _shape_bytes_between(line, eq, cm.start(1))
                c, bb = by_op.get(op, (0, 0))
                by_op[op] = (c + 1, bb + b)
            dm = _DOT_LINE_RE.match(line)
            if dm:
                res_dims = [int(d) for d in dm.group(2).split(",") if d]
                lhs_name = dm.group(3)
                ctr = _CONTRACT_RE.search(line)
                cdims = [int(d) for d in ctr.group(1).split(",") if d] if ctr else []
                lhs = sym.get(lhs_name)
                k = 1
                if lhs:
                    for i in cdims:
                        if i < len(lhs[1]):
                            k *= lhs[1][i]
                n = 1
                for d in res_dims:
                    n *= d
                flops += 2 * n * k
            if not fusion_body:
                im = _INSTR_RE.match(line)
                if im and not any(s in line for s in _SKIP_OPS):
                    dt_dims = _SHAPE_RE.match(im.group(2))
                    if dt_dims:
                        traffic += _bytes_of(dt_dims.group(1), dt_dims.group(2))
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 0
                while_edges[name].append((wm.group(1), wm.group(2), trips))
            elif " fusion(" in line or " call(" in line or "conditional(" in line:
                for ref in _CALLS_RE.findall(line):
                    call_edges[name].append(ref)
        raw_coll[name] = by_op
        raw_flops[name] = flops
        raw_traffic[name] = traffic

    totals: dict[str, tuple[int, int]] = {}
    total_flops = 0
    total_traffic = 0
    visiting: set[str] = set()

    def visit(name: str, mult: int):
        nonlocal total_flops, total_traffic
        if name not in comps or name in visiting:
            return
        visiting.add(name)
        for op, (c, b) in raw_coll.get(name, {}).items():
            cc, bb = totals.get(op, (0, 0))
            totals[op] = (cc + c, bb + b * mult)
        total_flops += raw_flops.get(name, 0) * mult
        total_traffic += raw_traffic.get(name, 0) * mult
        for cond, body, trips in while_edges.get(name, []):
            if trips <= 0:
                trips = cond_consts.get(cond, 1)
            visit(body, mult * max(trips, 1))
        for child in call_edges.get(name, []):
            visit(child, mult)
        visiting.discard(name)

    if entry:
        visit(entry, 1)
    by_op = {op: {"count": c, "bytes": int(b)} for op, (c, b) in totals.items()}
    return {
        "by_op": by_op,
        "total_bytes": int(sum(v["bytes"] for v in by_op.values())),
        "dot_flops_per_device": int(total_flops),
        "hbm_traffic_per_device": int(total_traffic),
    }


parse_collectives = parse_hlo  # back-compat alias


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, l = shape.global_batch, shape.seq_len
    out: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = sds((b, l), jnp.int32)
        if shape.kind == "train":
            out["labels"] = sds((b, l), jnp.int32)
        if arch.input_mode == "embeddings":
            out["embeds"] = sds((b, l, arch.d_model), jnp.bfloat16)
    else:  # decode: one new token against a cache of length l
        out["tokens"] = sds((b, 1), jnp.int32)
    return out


def build_cell(arch_name: str, shape_name: str, multi_pod: bool):
    """Returns (lower_fn, meta) for one cell; lower_fn() -> compiled."""
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = make_axes(mesh, shape.global_batch)
    model = Model(
        arch, ax,
        remat="full" if shape.kind == "train" else "none",
        remat_group=6 if arch.param_count() >= 100e9 else 1,
    )
    key = jax.random.PRNGKey(0)

    params_shape = jax.eval_shape(model.init, key)
    pspecs = apply_fsdp(model.param_specs(), params_shape,
                        fsdp_axis="data", fsdp_size=mesh.shape["data"])
    pshard = named(mesh, pspecs)
    ins = input_specs(arch, shape)

    if shape.kind == "train":
        tcfg = auto_train_config(arch.param_count(), shape.global_batch, dp_size(mesh), moe=arch.moe is not None)
        step = make_train_step(model, tcfg)
        opt_shape = jax.eval_shape(lambda p: init_opt_state(p, tcfg.opt), params_shape)
        ospecs = opt_state_specs(pspecs, ax, zero1=False)
        oshard = named(mesh, ospecs)
        bshard = named(mesh, batch_specs(model))
        bshard = {k: bshard[k] for k in ins}
        fn = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )

        def lower():
            with mesh:
                return fn.lower(params_shape, opt_shape, ins)

        meta = {"kind": "train", "microbatches": tcfg.microbatches}
        return lower, meta

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch, cache_len=shape.seq_len)

        cshard = named(mesh, model.cache_specs())
        bsp = {k: P(ax.b, *([None] * (len(v.shape) - 1))) for k, v in ins.items()}
        fn = jax.jit(
            prefill_step,
            in_shardings=(pshard, named(mesh, bsp)),
            out_shardings=(None, cshard),
        )

        def lower():
            with mesh:
                return fn.lower(params_shape, ins)

        return lower, {"kind": "prefill"}

    # decode
    serve = make_serve_step(model)
    cache_shape = jax.eval_shape(
        lambda: model.cache_init(shape.global_batch, shape.seq_len)
    )
    cshard = named(mesh, model.cache_specs())
    tok_shard = named(mesh, {"tokens": P(ax.b, None)})["tokens"]
    fn = jax.jit(
        serve,
        in_shardings=(pshard, cshard, tok_shard, None, None),
        out_shardings=(tok_shard, cshard),
        donate_argnums=(1,),
    )
    pos = sds((), jnp.int32)
    rng = sds((2,), jnp.uint32)

    def lower():
        with mesh:
            return fn.lower(params_shape, cache_shape, ins["tokens"], pos, rng)

    return lower, {"kind": "decode"}


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, out_dir: str,
             collect_hlo: bool = True, force: bool = False) -> dict[str, Any]:
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch_name}__{shape_name}__{mesh_name}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(arch, shape)
    rec: dict[str, Any] = {
        "cell": cell_id, "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "params": arch.param_count(), "active_params": arch.active_param_count(),
    }
    if not ok:
        rec.update(status="skip", reason=reason)
        _write(out_path, rec)
        return rec

    try:
        t0 = time.time()
        lower, meta = build_cell(arch_name, shape_name, multi_pod)
        lowered = lower()
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec.update(meta)
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            } if mem is not None else None
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis"] = f"unavailable: {e}"
        try:
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            rec["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals", "optimal_seconds")
                    or k.startswith("bytes accessed")
                    or k.startswith("utilization")
                )
            }
        except Exception as e:
            rec["cost_analysis"] = f"unavailable: {e}"
        if collect_hlo:
            try:
                text = compiled.as_text()
                census = parse_hlo(text)
                rec["collectives"] = {
                    "by_op": census["by_op"], "total_bytes": census["total_bytes"]
                }
                rec["dot_flops_per_device"] = census["dot_flops_per_device"]
                rec["hbm_traffic_per_device"] = census["hbm_traffic_per_device"]
                rec["hlo_bytes"] = len(text)
                del text
            except Exception as e:
                rec["collectives"] = f"unavailable: {e}"
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _write(out_path, rec)
    return rec


def _write(path: str, rec: dict) -> None:
    with open(path + ".tmp", "w") as f:
        json.dump(rec, f, indent=1)
    os.replace(path + ".tmp", path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()
    out_dir = args.out or os.path.abspath(RESULTS_DIR)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    archs = [args.arch] if args.arch else sorted(registry())
    shapes = [args.shape] if args.shape else list(SHAPES)
    if not args.all and args.arch is None:
        ap.error("pass --arch/--shape or --all")

    n_ok = n_err = n_skip = 0
    for multi in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, multi, out_dir, collect_hlo=not args.no_hlo,
                               force=args.force)
                tag = rec["status"]
                n_ok += tag == "ok"
                n_err += tag == "error"
                n_skip += tag == "skip"
                extra = ""
                if tag == "ok":
                    fl = rec.get("cost_analysis", {})
                    fl = fl.get("flops") if isinstance(fl, dict) else None
                    extra = f" flops={fl:.3e}" if fl else ""
                    extra += f" lower={rec['lower_s']}s compile={rec['compile_s']}s"
                if tag == "error":
                    extra = " " + rec["error"][:160]
                print(f"[{tag:5s}] {rec['cell']}{extra}", flush=True)
    print(f"done: ok={n_ok} err={n_err} skip={n_skip}")


if __name__ == "__main__":
    main()
