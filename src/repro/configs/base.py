"""Architecture & shape configuration schema for the LM substrate.

Every assigned architecture is a frozen ``ArchConfig``; input shapes are
``ShapeConfig`` rows. ``registry()`` maps --arch ids to configs.
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # expert FFN hidden dim
    num_shared: int = 0  # always-on shared experts (DeepSeek-style)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    router_z_weight: float = 0.0001
    # which layers are MoE: "all", "every_2" (odd layers), or "after_first"
    layer_rule: str = "all"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dims."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba block dims (Jamba mixer)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64  # rank of the data-dependent decay projection
    token_shift: bool = True


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "vlm", "hybrid", "ssm", "audio"]
    num_layers: int
    d_model: int
    num_heads: int  # query heads (0 for attention-free archs)
    num_kv_heads: int
    d_ff: int  # dense-FFN hidden (for MoE archs: the dense layers' width)
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    attention: Literal["gqa", "mla", "none"] = "gqa"
    window: int | None = None  # sliding-window attention width
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    # hybrid layer pattern, repeated to num_layers: e.g. Jamba period-8
    # ("m","m","m","a","m","m","m","m") — "a"=attention, "m"=mamba
    layer_pattern: tuple[str, ...] | None = None
    # modality frontend: "tokens" or "embeddings" (vlm/audio stub supplies
    # precomputed patch/frame embeddings for train/prefill)
    input_mode: Literal["tokens", "embeddings"] = "tokens"
    # which shapes need sub-quadratic attention support (long_500k)
    subquadratic: bool = False
    notes: str = ""

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def pattern(self) -> tuple[str, ...]:
        """Per-layer mixer types, length num_layers."""
        if self.layer_pattern is None:
            base = ("a",) if self.attention != "none" else ("r",)
            return base * self.num_layers
        reps = -(-self.num_layers // len(self.layer_pattern))
        return (self.layer_pattern * reps)[: self.num_layers]

    def moe_layer_mask(self) -> tuple[bool, ...]:
        """True where the FFN is MoE."""
        if self.moe is None:
            return (False,) * self.num_layers
        rule = self.moe.layer_rule
        if rule == "all":
            return (True,) * self.num_layers
        if rule == "every_2":
            return tuple(i % 2 == 1 for i in range(self.num_layers))
        if rule == "after_first":
            return tuple(i >= 1 for i in range(self.num_layers))
        raise ValueError(rule)

    def param_count(self) -> int:
        """Total parameters (embedding + per-layer), for roofline MODEL_FLOPS."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim()
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm_head
        moe_mask = self.moe_layer_mask()
        for i, kind in enumerate(self.pattern()):
            total += 2 * d  # norms
            if kind == "a":
                if self.attention == "mla" and self.mla is not None:
                    m = self.mla
                    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_head
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    total += self.num_heads * m.v_head_dim * d
                else:
                    total += d * self.num_heads * hd  # q
                    total += 2 * d * self.num_kv_heads * hd  # k, v
                    total += self.num_heads * hd * d  # o
            elif kind == "m":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                dt_rank = s.dt_rank or -(-d // 16)
                total += d * 2 * d_in  # in_proj
                total += d_in * s.d_conv  # conv
                total += d_in * (dt_rank + 2 * s.d_state)  # x_proj
                total += dt_rank * d_in + d_in  # dt_proj
                total += d_in * (s.d_state + 2)  # A_log, D
                total += d_in * d  # out_proj
            elif kind == "r":
                r = self.rwkv or RWKVConfig()
                total += 4 * d * d  # r, k, v, output
                total += d * d  # gate
                total += 2 * d * r.decay_lora  # data-dependent decay lora
                total += 6 * d  # mixes, u, etc (approx)
            if moe_mask[i] and self.moe is not None:
                e = self.moe
                total += d * e.num_experts  # router
                total += (e.num_experts + e.num_shared) * 3 * d * e.d_expert
            else:
                total += 3 * d * self.d_ff  # SwiGLU
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e = self.moe
        n_moe = sum(self.moe_layer_mask())
        all_experts = n_moe * (e.num_experts + e.num_shared) * 3 * self.d_model * e.d_expert
        active = n_moe * (e.top_k + e.num_shared) * 3 * self.d_model * e.d_expert
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]
    microbatch: int = 0  # 0 -> auto (train only)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason) — long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "SKIP(full-attention: 500k KV/prefill needs sub-quadratic mechanism)"
    return True, ""
