"""MusicGen-large [arXiv:2306.05284] — decoder-only transformer over
EnCodec tokens. 48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.
The EnCodec frontend + 4-codebook interleaving is a STUB: input_specs()
supplies precomputed frame embeddings; one 2048-way lm_head models the
per-codebook output (DESIGN §4)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    input_mode="embeddings",
    rope_theta=10_000.0,
    notes="EnCodec frontend stubbed; backbone faithful",
)
