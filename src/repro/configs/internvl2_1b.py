"""InternVL2-1B [arXiv:2404.16821] — InternViT-300M frontend + Qwen2-0.5B
backbone (vocab extended to 151655). The ViT frontend is a STUB:
input_specs() supplies precomputed patch embeddings (B, L, d_model); only
the LM backbone is modeled, per the assignment."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    input_mode="embeddings",
    notes="ViT frontend stubbed: train/prefill consume precomputed patch embeddings",
)
