"""Jamba v0.1 52B [arXiv:2403.19887] — Mamba+attention 1:7 interleave
(attn_layer_period=8, offset=4), MoE 16 experts top-2 on every other layer
(expert period 2, offset 1). 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536. Hybrid => runs long_500k (SSM state + 4 attention layers)."""
from .base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    layer_pattern=("m", "m", "m", "m", "a", "m", "m", "m"),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14_336, layer_rule="every_2"),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10_000.0,
    subquadratic=True,
)
