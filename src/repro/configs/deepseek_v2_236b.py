"""DeepSeek-V2 236B [arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2].

60L d_model=5120 128H, MLA (kv_lora_rank=512, q_lora_rank=1536,
qk_nope=128, qk_rope=64, v=128), dense FFN 12288 on layer 0
(first_k_dense_replace=1), MoE elsewhere: 160 routed experts top-6 +
2 shared, expert width 1536. vocab 102400.
"""
from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=12288,  # dense layers (layer 0)
    vocab_size=102_400,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536, num_shared=2,
                  layer_rule="after_first"),
    rope_theta=10_000.0,
    notes="MLA latent-KV decode (absorbed matmuls); 2 shared + 160 routed experts",
)
