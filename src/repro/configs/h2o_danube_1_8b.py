"""H2O-Danube 1.8B [arXiv:2401.16818] — llama+mistral mix with sliding
window attention. 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
Window 4096 (mistral-style) => sub-quadratic; runs long_500k decode with an
O(window) ring KV cache.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32_000,
    window=4096,
    rope_theta=10_000.0,
    subquadratic=True,
)
