"""Architecture registry: --arch <id> -> ArchConfig, plus reduced smoke
configs (same family/structure, tiny dims) for CPU tests."""
from __future__ import annotations

import dataclasses

from .base import SHAPES, ArchConfig, MLAConfig, MoEConfig, RWKVConfig, ShapeConfig, SSMConfig, shape_applicable  # noqa: F401


def registry() -> dict[str, ArchConfig]:
    from . import (
        deepseek_v2_236b,
        granite_moe_1b_a400m,
        h2o_danube_1_8b,
        internvl2_1b,
        jamba_v0_1_52b,
        llama3_2_3b,
        llama3_405b,
        musicgen_large,
        qwen2_0_5b,
        rwkv6_1_6b,
    )

    mods = [
        deepseek_v2_236b,
        granite_moe_1b_a400m,
        h2o_danube_1_8b,
        llama3_2_3b,
        qwen2_0_5b,
        llama3_405b,
        internvl2_1b,
        jamba_v0_1_52b,
        rwkv6_1_6b,
        musicgen_large,
    ]
    return {m.CONFIG.name: m.CONFIG for m in mods}


def get_config(name: str) -> ArchConfig:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have {sorted(reg)}")
    return reg[name]


def reduced_config(cfg: ArchConfig, vocab: int = 512) -> ArchConfig:
    """Structure-preserving tiny config for CPU smoke tests.

    Keeps: family, mixer kinds, layer pattern period, MoE routing shape
    (fewer experts), MLA structure (smaller ranks), GQA ratios.
    Shrinks: width, depth (>= one full pattern period), vocab.
    """
    period = len(cfg.layer_pattern) if cfg.layer_pattern else 2
    layers = max(period, 2)
    heads = max(2, min(cfg.num_heads, 4)) if cfg.num_heads else 0
    kvh = max(1, min(cfg.num_kv_heads, heads)) if cfg.num_kv_heads else 0
    if heads and cfg.num_kv_heads and cfg.num_heads % cfg.num_kv_heads == 0:
        kvh = max(1, heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    d_model = 64
    changes: dict = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kvh,
        head_dim=16 if heads else 0,
        d_ff=128,
        vocab_size=vocab,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(2, cfg.moe.top_k), d_expert=32
        )
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(
            kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=8, d_conv=4, expand=2)
    if cfg.rwkv is not None:
        changes["rwkv"] = RWKVConfig(head_size=16, decay_lora=8)
    if cfg.window is not None:
        changes["window"] = 16
    return dataclasses.replace(cfg, **changes)
