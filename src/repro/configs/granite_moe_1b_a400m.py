"""IBM Granite 3.0 1B-A400M base [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) MoE 32 experts top-8, expert width 512,
vocab 49155. All layers MoE, no shared experts.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512, layer_rule="all"),
    rope_theta=10_000.0,
)
