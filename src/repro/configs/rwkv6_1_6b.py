"""RWKV-6 (Finch) 1.6B [arXiv:2404.05892]. 24L d_model=2048, attention-free
(wkv6 time-mix with data-dependent decay), channel-mix d_ff=7168,
vocab=65536. O(1)-state decode => runs long_500k."""
from .base import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=7168,
    vocab_size=65_536,
    attention="none",
    rwkv=RWKVConfig(head_size=64, decay_lora=64),
    subquadratic=True,
    notes="attention-free: Binary Bleed applies only at meta level (DESIGN §Arch-applicability)",
)
