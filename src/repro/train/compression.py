"""Gradient compression for the data-parallel all-reduce.

At 256-1024 chips the step all-reduce of bf16 grads is the collective-term
floor. Two standard tricks, both implemented as pure pytree transforms
around the psum (so GSPMD schedules the smaller transfers):

  * bf16 cast (2x vs fp32 master grads),
  * int8 block-quantization with per-block fp scales (additional ~2x vs
    bf16; error feedback optional via the caller keeping the residual).

Quantize -> all-reduce -> dequantize is linear-safe for mean-reduction when
scales are shared; we use per-shard local quantization + fp32 scale
all-reduce, the scheme used by practical 1-bit/8-bit Adam variants.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any
_BLOCK = 256


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8: returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape: tuple[int, ...], dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_tree(grads: PyTree, mode: str = "none") -> PyTree:
    """Apply lossy compression to a grad pytree (round-trip, simulating the
    wire format the all-reduce would carry)."""
    if mode == "none":
        return grads
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    if mode == "int8":
        def roundtrip(g):
            q, s = quantize_int8(g)
            return dequantize_int8(q, s, g.shape, g.dtype)

        return jax.tree.map(roundtrip, grads)
    raise ValueError(f"unknown compression mode {mode!r}")
