"""Training step: microbatched grad accumulation + AdamW + sharding specs.

The step is ONE jit'd program:
  * ``lax.scan`` over microbatches — each microbatch's fwd/bwd is local
    (activations never exceed one microbatch); the summed gradient is
    all-reduced once by GSPMD at the boundary (compute/comm overlap comes
    from XLA scheduling the reduce against the next microbatch's compute),
  * optional gradient compression round-trip (bf16/int8) modelling the
    wire format,
  * AdamW with ZeRO-1 sharded state via out_shardings.

``make_train_step(model, opt_cfg, microbatches, compression)`` returns
(step_fn, batch_specs) ready for jit/lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.transformer import Model
from .compression import compress_tree
from .optimizer import AdamWConfig, OptState, adamw_update

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1  # grad-accumulation steps per optimizer step
    compression: str = "none"  # none | bf16 | int8
    accum_dtype: Any = jnp.float32  # bf16 halves the grad buffer at 405B


def auto_train_config(param_count: int, global_batch: int, dp: int, moe: bool = False) -> TrainConfig:
    """Memory-fitting defaults per model scale (see DESIGN §5 / EXPERIMENTS
    §Dry-run memory table + §Perf llama3-405b)."""
    if param_count >= 100e9 and not moe:
        # few microbatches = few FSDP weight-gather passes (§Perf iter B/D);
        # dense only — MoE dispatch buffers scale with microbatch size
        n, state, accum = 4, jnp.bfloat16, jnp.bfloat16
    elif param_count >= 100e9:
        n, state, accum = 16, jnp.bfloat16, jnp.bfloat16
    elif param_count >= 20e9:
        n, state, accum = 8, jnp.float32, jnp.float32
    elif param_count >= 2e9:
        n, state, accum = 4, jnp.float32, jnp.float32
    else:
        n, state, accum = 2, jnp.float32, jnp.float32
    n = max(1, min(n, global_batch // dp))
    while global_batch % n or (global_batch // n) % dp:
        n -= 1
    return TrainConfig(
        opt=AdamWConfig(state_dtype=state), microbatches=n, accum_dtype=accum
    )


def batch_specs(model: Model, shape_kind: str = "train") -> dict[str, P]:
    ax = model.ax
    specs = {"tokens": P(ax.b, None), "labels": P(ax.b, None)}
    if model.cfg.input_mode == "embeddings":
        specs["embeds"] = P(ax.b, None, None)
    return specs


def _split_microbatches(batch: PyTree, n: int) -> PyTree:
    """(B, ...) -> (n, B/n, ...) for scanning."""

    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(r, batch)


def make_train_step(
    model: Model, tcfg: TrainConfig
) -> Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState, dict[str, Array]]]:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def train_step(params: PyTree, opt_state: OptState, batch: PyTree):
        n = tcfg.microbatches
        loss_and_grad = jax.value_and_grad(model.loss_fn)

        if n == 1:
            loss, grads = loss_and_grad(params, batch)
        else:
            mb = _split_microbatches(batch, n)

            def acc_body(carry, mb_i):
                loss_sum, g_sum = carry
                loss_i, g_i = loss_and_grad(params, mb_i)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(tcfg.accum_dtype), g_sum, g_i
                )
                return (loss_sum + loss_i, g_sum), ()

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, tcfg.accum_dtype), params)
            (loss_sum, grads), _ = jax.lax.scan(acc_body, (jnp.zeros(()), g0), mb)
            loss = loss_sum / n
            grads = jax.tree.map(lambda g: g / n, grads)

        grads = compress_tree(grads, tcfg.compression)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state, tcfg.opt)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


def metric_specs() -> dict[str, P]:
    return {"loss": P(), "grad_norm": P(), "lr": P()}
