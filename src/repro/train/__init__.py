from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state, opt_state_specs  # noqa: F401
from .train_step import TrainConfig, auto_train_config, batch_specs, make_train_step  # noqa: F401
