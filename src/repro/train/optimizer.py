"""AdamW with explicit sharding hooks (ZeRO-1 style) and bf16-state option.

No optax dependency: at 405B scale the optimizer *is* a distribution
feature — m/v state specs mirror the param specs and are additionally
sharded over the 'data' axis on their largest dimension when legal (the
out_shardings on train_step make GSPMD materialize the reduce-scatter /
all-gather pattern of ZeRO).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32  # jnp.bfloat16 halves optimizer memory
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: Array
    m: PyTree
    v: PyTree


def init_opt_state(params: PyTree, cfg: AdamWConfig) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def opt_state_specs(param_specs: PyTree, axes, zero1: bool = True) -> OptState:
    """m/v inherit param specs; with zero1, add 'data' sharding on the first
    unsharded large axis (classic ZeRO-1 optimizer-state partitioning)."""

    def shard_more(spec: P) -> P:
        if not zero1:
            return spec
        entries = list(spec)
        for i, e in enumerate(entries):
            if e is None:
                entries[i] = "data"
                return P(*entries)
        return spec

    def _map(fn, tree):
        return jax.tree.map(fn, tree, is_leaf=lambda s: isinstance(s, P))

    return OptState(step=P(), m=_map(shard_more, param_specs), v=_map(shard_more, param_specs))


def lr_at(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree: PyTree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: PyTree, grads: PyTree, state: OptState, cfg: AdamWConfig
) -> tuple[PyTree, OptState, dict[str, Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1**step.astype(jnp.float32)
    b2c = 1 - cfg.b2**step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        mhat, vhat = m_new / b1c, v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(cfg.state_dtype), v_new.astype(cfg.state_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
