from .fault_tolerance import HeartbeatMonitor, ResourceView  # noqa: F401
from .straggler import SpeculationPolicy  # noqa: F401
