"""Control-plane fault tolerance for the distributed k-search + training.

On a 1000+-node cluster the failure model is: a resource (mesh slice /
host group) stops heartbeating mid-evaluation. Because Binary Bleed's unit
of work — "fit model at k, score it" — is pure and idempotent, recovery is
scheduling, not state surgery:

  * ``HeartbeatMonitor`` tracks liveness (injectable clock for tests),
  * on failure: the dead resource's unvisited chunk re-enters the pool and
    `core.chunking.rebalance` re-deals it (Algorithm 2 is stateless),
  * its in-flight k (never completed) is re-queued,
  * pruning state is NOT lost — it lives in the coordinator/journal, so the
    restarted search never re-visits completed k.

Training fits recover via checkpoint.restore (per-fit checkpoints), search
state via FileCoordinator.replay — both exercised in tests/test_runtime.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core.chunking import rebalance
from repro.core.traversal import Order
from repro.obs import get_metrics, get_tracer


@dataclasses.dataclass
class ResourceView:
    rid: int
    last_beat: float
    worklist: list[int]
    in_flight: int | None = None
    alive: bool = True


class HeartbeatMonitor:
    """Failure detector + elastic re-planner over resource worklists."""

    def __init__(
        self,
        worklists: dict[int, list[int]],
        timeout: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
        order: Order = "pre",
    ):
        self.clock = clock
        self.timeout = timeout
        self.order = order
        now = clock()
        self.resources = {
            rid: ResourceView(rid, now, list(wl)) for rid, wl in worklists.items()
        }
        self._next_rid = max(worklists, default=-1) + 1

    # -- liveness ---------------------------------------------------------------
    def beat(self, rid: int) -> None:
        if rid in self.resources and self.resources[rid].alive:
            self.resources[rid].last_beat = self.clock()

    def mark_in_flight(self, rid: int, k: int | None) -> None:
        if rid in self.resources:
            self.resources[rid].in_flight = k

    def check(self) -> list[int]:
        """Returns newly-dead rids and re-plans their work."""
        now = self.clock()
        ages = [now - r.last_beat for r in self.resources.values() if r.alive]
        if ages:
            get_metrics().set_gauge("heartbeat_age_max", max(ages))
        dead = [
            r.rid
            for r in self.resources.values()
            if r.alive and now - r.last_beat > self.timeout
        ]
        for rid in dead:
            self.fail(rid)
        return dead

    # -- elasticity ---------------------------------------------------------------
    def fail(self, rid: int) -> None:
        r = self.resources.get(rid)
        if r is None or not r.alive:
            return
        r.alive = False
        pool = list(r.worklist)
        requeued = r.in_flight
        if r.in_flight is not None:
            pool.append(r.in_flight)  # idempotent: safe to redo
            r.in_flight = None
        r.worklist = []
        get_metrics().inc("failures")
        get_tracer().event(
            "resource_failed", track="scheduler", rid=rid,
            requeued_in_flight=requeued, pool=len(pool),
        )
        self._redistribute(pool)

    def join(self, worklist: list[int] | None = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.resources[rid] = ResourceView(rid, self.clock(), worklist or [])
        if worklist is None:
            self._rebalance_all()
        get_metrics().inc("joins")
        get_tracer().event("resource_joined", track="scheduler", rid=rid)
        return rid

    def _survivors(self) -> list[ResourceView]:
        return [r for r in self.resources.values() if r.alive]

    def _redistribute(self, pool: list[int]) -> None:
        survivors = self._survivors()
        if not survivors:
            return
        merged = sorted(set(pool) | {k for r in survivors for k in r.worklist})
        if not merged:
            return
        new_lists = rebalance(merged, len(survivors), self.order)
        for r, wl in zip(sorted(survivors, key=lambda r: r.rid), new_lists):
            r.worklist = list(wl)

    def _rebalance_all(self) -> None:
        self._redistribute([])

    def remaining(self) -> set[int]:
        out = set()
        for r in self._survivors():
            out.update(r.worklist)
            if r.in_flight is not None:
                out.add(r.in_flight)
        return out
