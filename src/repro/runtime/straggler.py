"""Straggler mitigation for distributed k evaluations.

Model fits at different k have different durations (larger k = bigger
factors) and different hardware luck (a slow host, a thermally-throttled
chip). Because evaluations are idempotent, the classic MapReduce remedy
applies: when a resource idles and the tail evaluation's elapsed time
exceeds ``factor`` × the running median of completed durations, launch a
speculative duplicate; first finisher wins, the coordinator drops the
loser. ``SpeculationPolicy`` is the pure decision kernel (simulated +
threaded schedulers both call it; tested in isolation).
"""
from __future__ import annotations

import dataclasses
import statistics

from repro.obs import get_metrics, get_tracer


@dataclasses.dataclass
class SpeculationPolicy:
    factor: float = 1.5  # duplicate when elapsed > factor * median
    min_samples: int = 3  # need this many completions to trust the median
    max_duplicates: int = 1  # per k

    def __post_init__(self):
        self._durations: list[float] = []
        self._dup_counts: dict[int, int] = {}

    def observe_completion(self, k: int, duration: float) -> None:
        self._durations.append(duration)

    def should_speculate(self, k: int, elapsed: float) -> bool:
        if len(self._durations) < self.min_samples:
            return False
        if self._dup_counts.get(k, 0) >= self.max_duplicates:
            return False
        med = statistics.median(self._durations)
        return elapsed > self.factor * med

    def note_duplicate(self, k: int) -> None:
        self._dup_counts[k] = self._dup_counts.get(k, 0) + 1
        get_metrics().inc("speculations")
        get_tracer().event(
            "speculate", track="scheduler", k=k, duplicates=self._dup_counts[k]
        )

    def duplicates(self, k: int) -> int:
        """How many speculative duplicates were launched for ``k``."""
        return self._dup_counts.get(k, 0)
