"""Distributed + parallel Binary Bleed — the paper end-to-end (Fig 2-6).

Four "resources" (mesh slices on a pod; threads here) search K = {2..20}
concurrently: Algorithm 2 deals k values round-robin, each resource walks
its pre-order worklist, and threshold crossings broadcast prune bounds
through the shared coordinator. Each k evaluation is itself a distributed
NMF fit (shard_map over the resource's sub-mesh — the paper's pyDNMFk
mode). The journal makes the whole search restartable: kill this script
mid-run and re-run it — completed k values are never re-fit.

    PYTHONPATH=src python examples/distributed_ksearch.py
"""
import tempfile

from repro.launch.ksearch import main

journal = tempfile.mkdtemp(prefix="bleed_journal_")
out = main([
    "--n", "128", "--m", "144",
    "--k-true", "6",
    "--k-min", "2", "--k-max", "20",
    "--resources", "4",
    "--threshold", "0.9",
    "--early-stop",
    "--order", "pre",
    "--nmf-iters", "100",
    "--n-perturbs", "4",
    "--distributed-fit",
    "--journal", journal,
])
print(f"\nvisited {out['n_visited']}/{out['n_candidates']} k values "
      f"({100 * out['visit_fraction']:.0f}%) on {out['resources']} resources; "
      f"journal: {journal}")
assert out["k_optimal"] == 6
