"""End-to-end LM training driver on a reduced assigned architecture.

Trains a few hundred steps of the reduced granite-MoE config (real MoE
routing, grad accumulation, AdamW, async checkpointing + restart) on CPU.
Swap --no-reduced + a pod mesh for the real thing; the train_step lowered
here is byte-identical in structure to the dry-run's 256-chip program.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import tempfile

from repro.launch.train import main

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="granite-moe-1b-a400m")
args = ap.parse_args()

ckpt = tempfile.mkdtemp(prefix="lm_ckpt_")
out = main([
    "--arch", args.arch,
    "--steps", str(args.steps),
    "--batch", "16", "--seq", "64",
    "--microbatches", "4",
    "--lr", "1e-3",
    "--ckpt", ckpt, "--ckpt-every", "50",
])
drop = out["losses"][0] - out["final_loss"]
print(f"\nloss dropped {drop:.3f} nats over {args.steps} steps; checkpoints in {ckpt}")
assert drop > 0.3, "expected clear learning progress"
