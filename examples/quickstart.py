"""Quickstart: Binary Bleed in 30 lines.

Find the optimal NMF rank k for a synthetic dataset with a planted k=5,
comparing Binary Bleed against the standard exhaustive grid search.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import binary_bleed_search, grid_search
from repro.factorization import make_nmfk_evaluator, nmf_data

key = jax.random.PRNGKey(0)

# 1. a dataset with 5 latent components
v, _, _ = nmf_data(key, n=96, m=104, k_true=5)

# 2. the scorer: NMFk silhouette stability (jit'd JAX, perturbation ensemble)
evaluate = make_nmfk_evaluator(v, key, n_perturbs=4, nmf_iters=100)

# 3. Binary Bleed over K = {2..16} with select threshold 0.9
result = binary_bleed_search(
    evaluate,
    k_range=(2, 16),
    select_threshold=0.9,
    stop_threshold=0.2,  # Early Stop (paper §III-C)
    num_resources=1,     # serial Algorithm 1; >1 = parallel resources
)
baseline = grid_search(evaluate, (2, 16), select_threshold=0.9)

print(f"Binary Bleed : k_optimal={result.k_optimal} "
      f"visited {result.n_visited}/{result.n_candidates} "
      f"({100 * result.visit_fraction:.0f}% of K) -> {sorted(result.visited_ks)}")
print(f"Grid search  : k_optimal={baseline.k_optimal} "
      f"visited {baseline.n_visited}/{baseline.n_candidates} (100% of K)")
assert result.k_optimal == baseline.k_optimal == 5
