"""K-Means + Davies-Bouldin minimization with Early Stop (paper §IV-A).

The K-Means experiment from the paper: Gaussian blobs (std 0.5 + noise),
DB index as the score (LOWER is better -> minimization mode), Early Stop
pruning the upper k range once the score blows past the stop bound.

    PYTHONPATH=src python examples/kmeans_earlystop.py
"""
import jax

from repro.core import binary_bleed_search
from repro.core.scoring import davies_bouldin_score
from repro.factorization import blob_data, kmeans

key = jax.random.PRNGKey(1)
x, _ = blob_data(key, n=300, d=6, k_true=7, std=0.5, spread=8.0)


def evaluate(k: int, should_abort=None) -> float:
    res = kmeans(x, int(k), jax.random.fold_in(key, k))
    return float(davies_bouldin_score(x, res.labels, int(k)))


result = binary_bleed_search(
    evaluate,
    k_range=(2, 24),
    select_threshold=0.6,   # DB <= 0.6 selects (good separation)
    stop_threshold=1.6,     # DB >= 1.6 can never recover -> prune upward
    mode="minimize",
    num_resources=2,
)
print(f"k_optimal={result.k_optimal} (true 7), visited "
      f"{result.n_visited}/{result.n_candidates} k values: {sorted(result.visited_ks)}")
for v in sorted(result.visits, key=lambda v: v.k):
    print(f"  k={v.k:2d} DB={v.score:.3f}"
          + ("  <- selects" if v.pruned_lower else "")
          + ("  <- stops" if v.pruned_upper else ""))
