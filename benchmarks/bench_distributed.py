"""Paper Fig 9 + §IV-C: distributed-setting reduction — visit % and modeled
runtime for pyDNMFk/pyDRESCALk-style runs.

Paper: distributed NMF (K=2..8): pre-order visits 43% (51.4 min vs 120),
post-order 86%; distributed RESCAL (K=2..11): pre 30% (54 min vs 180),
post 80%.

We regenerate the *scheduling* numbers with real distributed fits (shard_map
NMF/RESCAL on the local mesh) supplying the score curves, and model runtime
as visits x measured per-k fit time (the paper's own accounting: avg
17.14 min/k NMF, 18 min/k RESCAL).
"""
from __future__ import annotations

import time

import jax

from repro.core import binary_bleed_worklist, make_space
from repro.factorization import (
    distributed_nmf,
    distributed_rescal,
    make_local_mesh,
    nmf_data,
    nmfk_score,
    rescal_data,
    rescalk_score,
)


def run(quick=True) -> list[tuple[str, float, str]]:
    key = jax.random.PRNGKey(2)
    mesh = make_local_mesh()
    rows = []

    # --- distributed NMF, K = 2..8 (paper's range), k_true=4 ---------------
    v, _, _ = nmf_data(key, n=160, m=176, k_true=4)
    t0 = time.perf_counter()
    distributed_nmf(v, 4, key, mesh, iters=100)  # one representative fit
    fit_s = time.perf_counter() - t0
    curve = {
        k: float(nmfk_score(v, k, jax.random.fold_in(key, k), n_perturbs=3, nmf_iters=80).min_silhouette)
        for k in range(2, 9)
    }
    for order in ("pre", "post"):
        space = make_space((2, 8), 0.55, 0.05)
        res = binary_bleed_worklist(space, lambda k: curve[k], order=order)
        pct = res.visit_fraction * 100
        # paper models runtime = visits x avg-per-k (17.14 min); ours in s
        rows.append((
            f"dist_nmf_{order}",
            pct,
            f"pct_visited; k_opt={res.k_optimal} (true 4); modeled_runtime="
            f"{res.n_visited * fit_s:.1f}s vs standard {7 * fit_s:.1f}s",
        ))

    # --- distributed RESCAL, K = 2..11, k_true=4 ----------------------------
    x, _, _ = rescal_data(key, n_entities=80, n_relations=4, k_true=4, noise=0.003)
    t0 = time.perf_counter()
    distributed_rescal(x, 4, key, mesh, iters=150)
    fit_r = time.perf_counter() - t0
    curve_r = {
        k: float(rescalk_score(x, k, jax.random.fold_in(key, 50 + k), n_perturbs=3, iters=150)[0])
        for k in range(2, 12)
    }
    for order in ("pre", "post"):
        space = make_space((2, 11), 0.8, 0.25)
        res = binary_bleed_worklist(space, lambda k: curve_r[k], order=order)
        rows.append((
            f"dist_rescal_{order}",
            res.visit_fraction * 100,
            f"pct_visited; k_opt={res.k_optimal} (true 4); modeled_runtime="
            f"{res.n_visited * fit_r:.1f}s vs standard {10 * fit_r:.1f}s",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
