"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json out.json]

Prints ``name,value,derived`` CSV rows:
  bench_visits      — Fig 7/8: % of K visited (NMFk + K-Means, 4 variants)
  bench_kmeans_rmse — §IV-A RMSE-of-recovered-k table
  bench_distributed — Fig 9: distributed NMF/RESCAL visit % + modeled runtime
  bench_chunking    — Table II: T1-T4 strategy ablation
  bench_kernels     — Pallas kernel parity + tile economics
  bench_scoring     — streaming vs dense silhouette: bytes moved + wall-clock
  bench_roofline    — §Roofline terms from the dry-run artifacts

``--json out.json`` additionally writes the structured results as
``{bench: {metric: value}}`` — the machine-readable form CI archives per
run so BENCH_*.json artifacts accumulate a perf trajectory over time.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full-scale (slow) settings")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write structured {bench: {metric: value}} results to OUT")
    args = ap.parse_args()
    quick = not args.full

    from . import (
        bench_chunking,
        bench_distributed,
        bench_kernels,
        bench_kmeans_rmse,
        bench_roofline,
        bench_scoring,
        bench_visits,
    )

    benches = {
        "chunking": bench_chunking.run,
        "kernels": bench_kernels.run,
        "kmeans_rmse": bench_kmeans_rmse.run,
        "distributed": bench_distributed.run,
        "visits": bench_visits.run,
        "scoring": bench_scoring.run,
        "roofline": bench_roofline.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    print("name,value,derived")
    failures = 0
    results: dict[str, dict[str, float]] = {}
    for name, fn in benches.items():
        t0 = time.time()
        results[name] = {}
        try:
            for row_name, value, derived in fn(quick=quick):
                print(f"{row_name},{value:.4f},{derived}")
                if math.isfinite(value):  # keep the JSON strict (no Infinity)
                    results[name][row_name] = float(value)
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{name},nan,ERROR {type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
