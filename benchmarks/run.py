"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json out.json]

Prints ``name,value,derived`` CSV rows:
  bench_visits      — Fig 7/8: % of K visited (NMFk + K-Means, 4 variants)
  bench_kmeans_rmse — §IV-A RMSE-of-recovered-k table
  bench_distributed — Fig 9: distributed NMF/RESCAL visit % + modeled runtime
  bench_chunking    — Table II: T1-T4 strategy ablation
  bench_kernels     — Pallas kernel parity + tile economics
  bench_scoring     — streaming vs dense silhouette: bytes moved + wall-clock
  bench_roofline    — §Roofline terms from the dry-run artifacts
  bench_sharded     — mesh-sharded wavefront: wave-throughput vs batched
  bench_collectives — pipelined ring collectives: sweep throughput + overlap
  bench_elastic     — elastic wavefront: sweeps saved vs fixed-iteration

``--json out.json`` additionally writes the structured results as
``{bench: {metric: value}}`` — the machine-readable form CI archives per
run so BENCH_*.json artifacts accumulate a perf trajectory over time.
Every artifact carries a ``_meta`` block (git SHA, ISO timestamp, JAX
backend/devices, package versions, and the run's metrics ``summary()``)
so artifacts from different PRs are comparable.

Quick-mode runs are additionally gated against
``benchmarks/baselines/BENCH_quick_baseline.json``: any metric the
baseline also records that regresses by more than 20% (in its bad
direction) fails the run — ``--regress-warn-only`` downgrades that to a
warning for machines whose timings aren't comparable to the baseline's.
"""
from __future__ import annotations

import argparse
import datetime
import json
import math
import os
import platform
import re
import subprocess
import sys
import time
import traceback


def _run_metadata() -> dict:
    """Provenance stamp for BENCH_*.json: without this, artifacts from
    different commits/machines are not comparable and the perf trajectory
    is noise."""
    meta: dict = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    sha = os.environ.get("GITHUB_SHA")
    if not sha:
        try:
            repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"],
                capture_output=True, text=True, cwd=repo_root, timeout=5,
            ).stdout.strip() or None
        except Exception:
            sha = None
    meta["git_sha"] = sha
    try:
        import jax
        import jaxlib
        import numpy

        meta["jax_backend"] = jax.default_backend()
        meta["devices"] = [str(d) for d in jax.devices()]
        meta["versions"] = {
            "jax": jax.__version__,
            "jaxlib": jaxlib.__version__,
            "numpy": numpy.__version__,
        }
    except Exception as e:  # pragma: no cover - jax is a hard dep in practice
        meta["jax_backend"] = f"unavailable: {type(e).__name__}"
    return meta


def _direction(metric: str) -> int:
    """+1 if larger is better, -1 if smaller is better, 0 if unknown.

    Matches the repo's metric naming: timings end in ``_us``/``_s`` (often
    with a ``_n4096``-style size suffix), kernel rows are ``kernel_*``
    microseconds, ratios/flags/speedups are higher-better. Unknown metrics
    (counts, percentages whose good direction depends on the table) are
    not gated — a wrong guess here would turn an improvement into a CI
    failure.
    """
    if any(t in metric for t in ("speedup", "scaling", "match", "overlap_fraction")):
        return 1
    if any(t in metric for t in ("overhead", "seconds", "rel_err", "shapes_compiled")):
        return -1
    core = re.sub(r"_[nl]\d+$", "", metric)  # strip size/lane suffixes
    if core.endswith(("_ratio", "_ok")):
        return 1
    if core.endswith(("_us", "_s")) or metric.startswith("kernel_"):
        return -1
    return 0


def check_regressions(
    results: dict, baseline: dict, threshold: float = 0.20
) -> list[str]:
    """Metrics worse than baseline by > threshold (in their bad direction)."""
    bad = []
    for bench, metrics in baseline.items():
        if bench.startswith("_") or bench not in results:
            continue
        for metric, base in metrics.items():
            cur = results[bench].get(metric)
            d = _direction(metric)
            if cur is None or d == 0 or not base:
                continue
            rel = (cur - base) / abs(base) * d  # positive = improvement
            if rel < -threshold:
                bad.append(
                    f"{bench}/{metric}: {cur:.4g} vs baseline {base:.4g} "
                    f"({-rel * 100:.0f}% worse)"
                )
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full-scale (slow) settings")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write structured {bench: {metric: value}} results to OUT")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                         "baselines", "BENCH_quick_baseline.json"),
                    metavar="JSON", help="quick-mode regression baseline")
    ap.add_argument("--regress-warn-only", action="store_true",
                    help="report >20%% quick-mode regressions without failing")
    args = ap.parse_args()
    quick = not args.full

    from repro.obs import Metrics, use_metrics

    from . import (
        bench_chunking,
        bench_collectives,
        bench_distributed,
        bench_elastic,
        bench_kernels,
        bench_kmeans_rmse,
        bench_obs_overhead,
        bench_roofline,
        bench_scoring,
        bench_sharded,
        bench_visits,
    )

    benches = {
        "chunking": bench_chunking.run,
        "kernels": bench_kernels.run,
        "kmeans_rmse": bench_kmeans_rmse.run,
        "distributed": bench_distributed.run,
        "visits": bench_visits.run,
        "scoring": bench_scoring.run,
        "roofline": bench_roofline.run,
        "obs_overhead": bench_obs_overhead.run,
        "sharded": bench_sharded.run,
        "collectives": bench_collectives.run,
        "elastic": bench_elastic.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    print("name,value,derived")
    failures = 0
    results: dict = {}
    run_metrics = Metrics()  # one registry per harness run; stamped into _meta
    for name, fn in benches.items():
        t0 = time.time()
        results[name] = {}
        try:
            with use_metrics(run_metrics):
                for row_name, value, derived in fn(quick=quick):
                    print(f"{row_name},{value:.4f},{derived}")
                    if math.isfinite(value):  # keep the JSON strict (no Infinity)
                        results[name][row_name] = float(value)
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{name},nan,ERROR {type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.json:
        meta = _run_metadata()
        meta["benches_run"] = sorted(benches)
        meta["quick"] = quick
        meta["metrics"] = run_metrics.summary()
        results["_meta"] = meta
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)
    # quick-mode perf gate: compare against the committed baseline (only
    # metrics the baseline records, only those with a known good direction)
    if quick and args.baseline and os.path.exists(args.baseline):
        with open(args.baseline) as f:
            baseline = json.load(f)
        regressions = check_regressions(results, baseline)
        for msg in regressions:
            print(f"# REGRESSION {msg}", flush=True)
        if regressions and not args.regress_warn_only:
            failures += 1
        elif regressions:
            print(f"# {len(regressions)} regression(s) ignored (--regress-warn-only)",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
