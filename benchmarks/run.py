"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json out.json]

Prints ``name,value,derived`` CSV rows:
  bench_visits      — Fig 7/8: % of K visited (NMFk + K-Means, 4 variants)
  bench_kmeans_rmse — §IV-A RMSE-of-recovered-k table
  bench_distributed — Fig 9: distributed NMF/RESCAL visit % + modeled runtime
  bench_chunking    — Table II: T1-T4 strategy ablation
  bench_kernels     — Pallas kernel parity + tile economics
  bench_scoring     — streaming vs dense silhouette: bytes moved + wall-clock
  bench_roofline    — §Roofline terms from the dry-run artifacts

``--json out.json`` additionally writes the structured results as
``{bench: {metric: value}}`` — the machine-readable form CI archives per
run so BENCH_*.json artifacts accumulate a perf trajectory over time.
Every artifact carries a ``_meta`` block (git SHA, ISO timestamp, JAX
backend/devices, package versions, and the run's metrics ``summary()``)
so artifacts from different PRs are comparable.
"""
from __future__ import annotations

import argparse
import datetime
import json
import math
import os
import platform
import subprocess
import sys
import time
import traceback


def _run_metadata() -> dict:
    """Provenance stamp for BENCH_*.json: without this, artifacts from
    different commits/machines are not comparable and the perf trajectory
    is noise."""
    meta: dict = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    sha = os.environ.get("GITHUB_SHA")
    if not sha:
        try:
            repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"],
                capture_output=True, text=True, cwd=repo_root, timeout=5,
            ).stdout.strip() or None
        except Exception:
            sha = None
    meta["git_sha"] = sha
    try:
        import jax
        import jaxlib
        import numpy

        meta["jax_backend"] = jax.default_backend()
        meta["devices"] = [str(d) for d in jax.devices()]
        meta["versions"] = {
            "jax": jax.__version__,
            "jaxlib": jaxlib.__version__,
            "numpy": numpy.__version__,
        }
    except Exception as e:  # pragma: no cover - jax is a hard dep in practice
        meta["jax_backend"] = f"unavailable: {type(e).__name__}"
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full-scale (slow) settings")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write structured {bench: {metric: value}} results to OUT")
    args = ap.parse_args()
    quick = not args.full

    from repro.obs import Metrics, use_metrics

    from . import (
        bench_chunking,
        bench_distributed,
        bench_kernels,
        bench_kmeans_rmse,
        bench_obs_overhead,
        bench_roofline,
        bench_scoring,
        bench_visits,
    )

    benches = {
        "chunking": bench_chunking.run,
        "kernels": bench_kernels.run,
        "kmeans_rmse": bench_kmeans_rmse.run,
        "distributed": bench_distributed.run,
        "visits": bench_visits.run,
        "scoring": bench_scoring.run,
        "roofline": bench_roofline.run,
        "obs_overhead": bench_obs_overhead.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    print("name,value,derived")
    failures = 0
    results: dict = {}
    run_metrics = Metrics()  # one registry per harness run; stamped into _meta
    for name, fn in benches.items():
        t0 = time.time()
        results[name] = {}
        try:
            with use_metrics(run_metrics):
                for row_name, value, derived in fn(quick=quick):
                    print(f"{row_name},{value:.4f},{derived}")
                    if math.isfinite(value):  # keep the JSON strict (no Infinity)
                        results[name][row_name] = float(value)
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{name},nan,ERROR {type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.json:
        meta = _run_metadata()
        meta["benches_run"] = sorted(benches)
        meta["quick"] = quick
        meta["metrics"] = run_metrics.summary()
        results["_meta"] = meta
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
