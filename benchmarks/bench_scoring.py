"""Streaming vs dense silhouette scoring: bytes moved + wall-clock.

The dense T_scorer path materializes the (n, n) distance matrix in HBM and
immediately reduces it to (n, k) cluster dist-sums — ~8n^2 bytes of traffic
(write + read back) for 4nk bytes of useful output. The streaming tiers
(`repro.core.scoring.cluster_dist_sums`: blocked jnp / fused Pallas) keep
every distance strip/tile on-chip, so traffic drops to the O(n*d + n*k)
operand/output floor.

Rows per n:
  scoring_dense_us_nX / scoring_stream_us_nX — wall-clock (dense skipped
      where the (n, n) block exceeds the scoring arena budget);
  scoring_bytes_ratio_nX — dense/stream bytes, measured via XLA
      ``cost_analysis`` when available, else the analytic traffic model;
  scoring_stream_ok_nX — 1.0 when streaming completed at an n whose dense
      (n, n) allocation is infeasible under the arena budget.

The arena budget models the per-score HBM slice a wavefront lane may claim
(many lanes share the device); quick mode uses 32 MiB so the regime where
dense dies but streaming survives is reachable on CPU in seconds.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import scoring
from repro.kernels import ops as kernel_ops

_D, _K = 32, 8


def _time(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def _measured_bytes(fn, *args) -> float | None:
    """XLA-reported HBM traffic for the compiled fn, when the backend says."""
    try:
        cost = jax.jit(fn).lower(*args).compile().cost_analysis()
        if isinstance(cost, list):  # older jax returns one dict per device
            cost = cost[0]
        return float(cost["bytes accessed"])
    except Exception:
        return None


def _model_bytes_dense(n: int) -> float:
    # write D (4n^2) + read D back for the contraction (4n^2) + operands/out
    return 8.0 * n * n + 4.0 * n * (_D + 2 * _K)


def _model_bytes_stream(n: int, block_rows: int) -> float:
    # per strip: x block + full x + onehot re-read; out written once
    n_blocks = -(-n // block_rows)
    return 4.0 * (n_blocks * (block_rows * _D + n * _D + n * _K) + n * _K)


def run(quick: bool = True) -> list[tuple[str, float, str]]:
    key = jax.random.PRNGKey(0)
    sizes = [1024, 4096] if quick else [1024, 4096, 16384]
    budget = (32 if quick else 512) * 1024 * 1024  # scoring arena, bytes
    block_rows = 512
    rows: list[tuple[str, float, str]] = []

    def dense(x, onehot):
        return jnp.matmul(jnp.sqrt(scoring.pairwise_sq_dists(x)), onehot)

    def stream(x, onehot):
        return scoring._cluster_dist_sums_blocked(x, onehot, block_rows)

    # Pallas parity at a small n (interpret mode makes large-n timing moot —
    # on TPU the fused kernel replaces the blocked tier wholesale)
    x = jax.random.normal(key, (256, _D))
    onehot = jax.nn.one_hot(jax.random.randint(key, (256,), 0, _K), _K)
    err = float(
        jnp.max(jnp.abs(kernel_ops.silhouette_dist_sums(x, onehot) - dense(x, onehot)))
        / jnp.maximum(jnp.max(jnp.abs(dense(x, onehot))), 1e-12)
    )
    rows.append(("scoring_pallas_rel_err", err, "fused kernel vs dense oracle, n=256"))

    for n in sizes:
        kx, kl = jax.random.split(jax.random.fold_in(key, n))
        x = jax.random.normal(kx, (n, _D))
        onehot = jax.nn.one_hot(jax.random.randint(kl, (n,), 0, _K), _K)

        dense_bytes = _measured_bytes(dense, x, onehot) or _model_bytes_dense(n)
        stream_bytes = _measured_bytes(stream, x, onehot) or _model_bytes_stream(n, block_rows)
        rows.append(
            (
                f"scoring_bytes_ratio_n{n}",
                dense_bytes / stream_bytes,
                f"dense={dense_bytes / 1e6:.1f}MB stream={stream_bytes / 1e6:.1f}MB",
            )
        )

        dense_feasible = 4.0 * n * n <= budget
        if dense_feasible:
            us = _time(jax.jit(dense), x, onehot)
            rows.append((f"scoring_dense_us_n{n}", us, f"(n,n)={4.0 * n * n / 1e6:.0f}MB in arena"))
        else:
            rows.append(
                (
                    f"scoring_dense_us_n{n}",
                    float("inf"),
                    f"infeasible: (n,n)={4.0 * n * n / 1e6:.0f}MB > arena {budget / 1e6:.0f}MB",
                )
            )
        us = _time(jax.jit(stream), x, onehot)
        peak = 4.0 * block_rows * n
        rows.append((f"scoring_stream_us_n{n}", us, f"peak_strip={peak / 1e6:.1f}MB"))
        if not dense_feasible:
            rows.append(
                (
                    f"scoring_stream_ok_n{n}",
                    1.0,
                    f"streaming completed where dense (n,n) exceeds the {budget / 1e6:.0f}MB arena",
                )
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
