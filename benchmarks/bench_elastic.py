"""Elastic wavefront executor: sweeps saved vs the fixed-iteration plane.

Acceptance bench for continuous batching over the k-search: run the same
|K| = 31 NMFk search through the fixed-iteration batched executor and the
elastic executor (convergence-gated chunked fits + lane refill + warm
starts) at the plane's default ``tol`` and report

  * **sweep speedup** — total MU sweeps the fixed-iteration schedule would
    pay for the elastic run's visit set (``n_perturbs * nmf_iters`` per
    submitted k, the plane's ``sweeps_fixed_total``) over the sweeps the
    elastic run actually executed. The gate must buy >= 1.5x here; the
    accounting identity ``sweeps_run + sweeps_saved == sweeps_fixed_total``
    is asserted (and reported as a gate-able 0/1 row) so the savings are
    provably bookkept, not sampled,
  * k_opt agreement between the two executors (the savings must be free:
    at the selected rank the gated scores track the oracle — off-optimum
    ranks measure ensemble stability, chaotic under any schedule change),
  * measured wall seconds for both (transparency; wall clock on this
    shared-core container also reflects the saved sweeps),
  * warm-start hit count and compiled-shape count (the chunked schedule
    must hold to a handful of bucketed (batch, k_pad) jit shapes),
  * a tol ablation: sweep speedup at {4x default, default, tol=0}; tol=0
    is the draw-for-draw oracle, so its speedup is exactly the eviction
    share and its scores must match the batched plane bitwise.

Single-process and single-device by design — the elastic win is schedule
elasticity, not device count; ``bench_sharded`` owns the mesh story.
"""
from __future__ import annotations

import inspect
import time


def _search_batched(v, key, space, fit):
    from repro.core import WavefrontScheduler
    from repro.factorization.planes import NMFkBatchPlane

    plane = NMFkBatchPlane(
        v, key, n_perturbs=fit["n_perturbs"], nmf_iters=fit["nmf_iters"],
        k_pad=fit["k_pad"],
    )
    t0 = time.perf_counter()
    res = WavefrontScheduler(space).run(plane)
    return res, plane, time.perf_counter() - t0


def _search_elastic(v, key, space, fit, tol):
    from repro.core import ElasticWavefrontScheduler
    from repro.factorization.planes import NMFkElasticPlane

    plane = NMFkElasticPlane(
        v, key, n_perturbs=fit["n_perturbs"], nmf_iters=fit["nmf_iters"],
        k_pad=fit["k_pad"], tol=tol, warm_start=tol > 0,
    )
    t0 = time.perf_counter()
    res = ElasticWavefrontScheduler(space).run(plane)
    return res, plane, time.perf_counter() - t0


def run(quick=True) -> list[tuple[str, float, str]]:
    import jax

    from repro.core import make_space
    from repro.factorization.planes import NMFkElasticPlane
    from repro.factorization.synthetic import nmf_data

    n, m = (192, 208) if not quick else (96, 104)
    iters = 200 if not quick else 150
    key = jax.random.PRNGKey(0)
    v, _, _ = nmf_data(key, n=n, m=m, k_true=5)
    fit = dict(n_perturbs=3, nmf_iters=iters, k_pad=32)  # |K| = 31
    space = lambda: make_space((2, 32), 0.9)  # noqa: E731

    default_tol = inspect.signature(NMFkElasticPlane.__init__).parameters["tol"].default
    res_b, plane_b, wall_b = _search_batched(v, key, space(), fit)
    res_e, plane_e, wall_e = _search_elastic(v, key, space(), fit, tol=default_tol)

    speedup = plane_e.sweeps_fixed_total / max(plane_e.sweeps_run, 1)
    accounting_ok = float(
        plane_e.sweeps_run + plane_e.sweeps_saved == plane_e.sweeps_fixed_total
    )
    match = float(res_b.k_optimal == res_e.k_optimal)

    # tol ablation (tol=0 == the fixed-iteration oracle, draw-for-draw)
    ablation = []
    for label, tol in (("tol4x", 4 * default_tol), ("tol0", 0.0)):
        res_a, plane_a, _ = _search_elastic(v, key, space(), fit, tol=tol)
        sp = plane_a.sweeps_fixed_total / max(plane_a.sweeps_run, 1)
        ablation.append((label, tol, sp, res_a.k_optimal, plane_a, res_a))

    _, _, sp0, k0, plane_0, res_0 = ablation[-1]
    oracle = dict(zip(res_b.visited_ks, (rec.score for rec in res_b.visits)))
    dev0 = max(
        (abs(rec.score - oracle[rec.k]) for rec in res_0.visits if rec.k in oracle),
        default=float("inf"),
    )

    rows = [
        (
            "elastic_sweeps_speedup_x",
            speedup,
            f"fixed-iteration sweeps / sweeps run at default tol={default_tol:g}: "
            f"{plane_e.sweeps_fixed_total} -> {plane_e.sweeps_run} "
            f"({plane_e.sweeps_saved} saved; gate >= 1.5x)",
        ),
        (
            "elastic_k_opt_match",
            match,
            f"k_opt batched={res_b.k_optimal} elastic={res_e.k_optimal} "
            f"(|K|={len(space().ks)})",
        ),
        (
            "elastic_accounting_ok",
            accounting_ok,
            f"sweeps_run + sweeps_saved == sweeps_fixed_total: "
            f"{plane_e.sweeps_run} + {plane_e.sweeps_saved} == "
            f"{plane_e.sweeps_fixed_total}",
        ),
        (
            "elastic_wall_s",
            wall_e,
            f"measured wall; fixed-iteration batched {wall_b:.1f}s "
            f"({plane_e.n_ticks} chunk dispatches)",
        ),
        (
            "elastic_warm_start_hits",
            float(plane_e.warm_cache.hits),
            f"refilled lanes seeded from a neighbor's W "
            f"({plane_e.warm_cache.misses} cold)",
        ),
        (
            "elastic_shapes_compiled",
            float(len(plane_e.shapes_compiled)),
            f"distinct (batch, k_pad) jit shapes: {sorted(plane_e.shapes_compiled)}",
        ),
        (
            "elastic_oracle_dev_tol0",
            dev0,
            f"max |score - batched| at tol=0 over {len(res_0.visits)} visits "
            f"(must be ~0: draw-for-draw oracle; k_opt={k0}, "
            f"eviction-only speedup {sp0:.2f}x)",
        ),
    ]
    for label, tol, sp, k_opt, plane_a, _ in ablation[:-1]:
        rows.append((
            f"elastic_speedup_{label}_x",
            sp,
            f"sweep speedup at tol={tol:g} (k_opt={k_opt}, "
            f"{plane_a.sweeps_saved} sweeps saved)",
        ))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
