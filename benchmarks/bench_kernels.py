"""Kernel micro-benchmarks: jnp-oracle timing on CPU + kernel/oracle parity
+ statically-derived TPU tile economics (VMEM working set, arithmetic
intensity). Wall-clock kernel timing is meaningless in interpret mode —
the TPU-relevant numbers here are the derived tile stats.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick=True) -> list[tuple[str, float, str]]:
    key = jax.random.PRNGKey(0)
    rows = []

    # NMF MU update: 512x512, k=32 (tile 128x128, k padded 32->32 sublane)
    n, m, k = 512, 512, 32
    v = jax.random.uniform(key, (n, m))
    w = jax.random.uniform(key, (n, k), minval=0.1)
    h = jax.random.uniform(key, (k, m), minval=0.1)
    us = _time(jax.jit(ref.mu_update_h_ref), v, w, h)
    got = ops.mu_update_h(v, w, h)
    err = float(jnp.max(jnp.abs(got - ref.mu_update_h_ref(v, w, h))))
    vmem_kb = (128 * 128 * 4 + 128 * k * 4 + k * 128 * 4 * 2 + k * k * 4) / 1024
    ai = (2 * n * k) / (4 * (n + k))  # flops/byte per output column tile
    rows.append(("kernel_nmf_h_update", us,
                 f"jnp_oracle_us; kernel_max_err={err:.2e} vmem_tile={vmem_kb:.0f}KiB AI={ai:.0f}"))

    # pairwise distances 512x512x64
    x = jax.random.normal(key, (512, 64))
    y = jax.random.normal(jax.random.fold_in(key, 1), (512, 64))
    us = _time(jax.jit(ref.pairwise_sq_dists_ref), x, y)
    err = float(jnp.max(jnp.abs(ops.pairwise_sq_dists(x, y) - ref.pairwise_sq_dists_ref(x, y))))
    rows.append(("kernel_pairwise", us, f"jnp_oracle_us; kernel_max_err={err:.2e}"))

    # flash attention B1 H8/2 L512 D64
    q = jax.random.normal(key, (1, 8, 512, 64))
    kk = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 512, 64))
    vv = jax.random.normal(jax.random.fold_in(key, 3), (1, 2, 512, 64))
    us = _time(jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True)), q, kk, vv)
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q, kk, vv) - ref.attention_ref(q, kk, vv))))
    # flash VMEM: q/k/v tiles + acc (bq=128, d=64->pad 128)
    vmem_kb = (128 * 128 * 4 * 4 + 128 * 2 * 4) / 1024
    rows.append(("kernel_flash_attention", us,
                 f"jnp_oracle_us; kernel_max_err={err:.2e} vmem_tile={vmem_kb:.0f}KiB"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
