"""Sharded wavefront executor: wave-throughput vs the single-device plane.

Acceptance bench for the mesh-sharded evaluation plane: run the same
|K|>=31 NMFk search through the batched (single-device) and sharded
(8-lane mesh) executors and report

  * measured wall seconds for both (transparency — on this 1-core CPU
    container the 8 "devices" timeshare one core, so wall clock cannot
    show the parallel win),
  * **modeled wave-throughput speedup** from lane-round accounting, the
    same modeling style as ``bench_distributed``'s modeled_runtime: the
    batched plane fits its padded lanes on one device (lane-slots add up;
    |K|=31 costs 1+2+4+8+16 = 31 slots), the L-lane mesh fits L lanes per
    round (ceil(padded/L) rounds per wave; 8 lanes cost 6 rounds) — with
    one lane-slot's fit time measured from the batched run,
  * k_opt agreement between the two executors,
  * compiled (batch, k_pad) shape counts (bucketing must hold each
    executor's search to a handful of jit shapes; sharded <= 4),
  * modeled scaling over lanes in {1, 2, 4, 8}.

The measurement needs 8 XLA devices, so the bench re-execs itself as a
child process with ``--xla_force_host_platform_device_count=8`` (the flag
must precede jax init — the parent harness has already initialized a
1-device runtime) and parses one JSON line back.
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import sys

_CHILD_FLAG = "--child"


def _child_main(full: bool) -> dict:
    import time

    import jax

    from repro.core import WavefrontScheduler, make_space
    from repro.factorization.batching import bucket_batch
    from repro.factorization.planes import NMFkBatchPlane
    from repro.factorization.synthetic import nmf_data

    n, m = (192, 208) if full else (96, 104)
    k_hi = 48 if full else 32
    iters = 100 if full else 60
    key = jax.random.PRNGKey(0)
    v, _, _ = nmf_data(key, n=n, m=m, k_true=5)
    space = make_space((2, k_hi), 0.9)

    class RecordingPlane(NMFkBatchPlane):
        """Keeps the padded size of every dispatch for lane-slot accounting."""

        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.dispatch_sizes: list[int] = []

        def _pad_ks(self, ks):
            padded, k_pad, n_real = super()._pad_ks(ks)
            self.dispatch_sizes.append(len(padded))
            return padded, k_pad, n_real

    def search(mesh):
        plane = RecordingPlane(
            v, key, n_perturbs=3, nmf_iters=iters, k_pad=k_hi, mesh=mesh
        )
        sched = WavefrontScheduler(space)
        t0 = time.perf_counter()
        res = sched.run(plane)
        wall = time.perf_counter() - t0
        return res, plane, sched, wall

    res_b, plane_b, sched_b, wall_b = search(mesh=None)
    lanes = min(8, jax.device_count())
    mesh = jax.make_mesh((lanes, 1), ("lane", "data"), devices=jax.devices()[:lanes])
    res_s, plane_s, sched_s, wall_s = search(mesh=mesh)

    # lane-round accounting: batched = one lane-slot per padded lane;
    # sharded = one round per ceil(padded / lanes)
    slots_b = sum(plane_b.dispatch_sizes)
    rounds_s = sum(math.ceil(sz / lanes) for sz in plane_s.dispatch_sizes)
    slot_s = wall_b / max(slots_b, 1)  # measured per-lane-slot fit seconds

    # modeled scaling: replay the batched search's wave chunk sizes through
    # the bucketing policy at each lane count (the wave trajectory is
    # executor-independent — same scores, same pruning)
    chunks = [len(w.ks) for w in sched_b.waves]
    scaling = {}
    for L in (1, 2, 4, 8):
        compiled: set[int] = set()
        rounds = 0
        for c in chunks:
            b = bucket_batch(c, lanes=L, bucket_min=L, compiled=compiled)
            compiled.add(b)
            rounds += math.ceil(b / L)
        scaling[L] = slots_b / max(rounds, 1)

    return {
        "k_candidates": space.n_candidates if hasattr(space, "n_candidates") else k_hi - 1,
        "k_batched": res_b.k_optimal,
        "k_sharded": res_s.k_optimal,
        "wall_batched_s": wall_b,
        "wall_sharded_s": wall_s,
        "lane_slots_batched": slots_b,
        "lane_rounds_sharded": rounds_s,
        "wave_speedup_modeled": slots_b / max(rounds_s, 1),
        "modeled_batched_s": slot_s * slots_b,
        "modeled_sharded_s": slot_s * rounds_s,
        "shapes_batched": sorted(plane_b.shapes_compiled),
        "shapes_sharded": sorted(plane_s.shapes_compiled),
        "scaling": {str(k): v for k, v in scaling.items()},
        "lanes": lanes,
    }


def _spawn_child(full: bool) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo_root, "src"), env.get("PYTHONPATH")) if p
    )
    cmd = [sys.executable, "-m", "benchmarks.bench_sharded", _CHILD_FLAG]
    if full:
        cmd.append("--full")
    proc = subprocess.run(
        cmd, capture_output=True, text=True, cwd=repo_root, env=env, timeout=1800
    )
    if proc.returncode != 0:
        raise RuntimeError(f"sharded bench child failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(quick=True) -> list[tuple[str, float, str]]:
    r = _spawn_child(full=not quick)
    match = float(r["k_batched"] == r["k_sharded"])
    rows = [
        (
            "sharded_wave_speedup_x",
            r["wave_speedup_modeled"],
            f"modeled lane-round speedup at lanes={r['lanes']}: "
            f"{r['lane_slots_batched']} slots -> {r['lane_rounds_sharded']} rounds "
            f"({r['modeled_batched_s']:.1f}s -> {r['modeled_sharded_s']:.1f}s)",
        ),
        (
            "sharded_k_opt_match",
            match,
            f"k_opt batched={r['k_batched']} sharded={r['k_sharded']}",
        ),
        (
            "sharded_shapes_compiled",
            float(len(r["shapes_sharded"])),
            f"distinct (batch, k_pad) jit shapes: {r['shapes_sharded']} "
            f"(batched plane: {len(r['shapes_batched'])})",
        ),
        (
            "sharded_wall_s",
            r["wall_sharded_s"],
            f"measured wall (8 virtual devices timeshare this host's core); "
            f"batched {r['wall_batched_s']:.1f}s",
        ),
    ]
    for L, sp in sorted(r["scaling"].items(), key=lambda kv: int(kv[0])):
        rows.append((f"sharded_scaling_l{L}", sp, "modeled speedup vs single device"))
    return rows


if __name__ == "__main__":
    if _CHILD_FLAG in sys.argv:
        print(json.dumps(_child_main(full="--full" in sys.argv)))
    else:
        for row in run():
            print(row)
