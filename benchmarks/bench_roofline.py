"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod mesh:
    compute term    = HLO_dot_FLOPs_global / (chips x 197e12 FLOP/s)
    memory term     = HBM_traffic_global   / (chips x 819e9 B/s)
    collective term = collective_bytes_per_device / 50e9 B/s/link
plus dominant term, MODEL_FLOPS = 6*N_active*D, usefulness ratio, and a
one-line lever. HLO quantities are parsed from the compiled SPMD module
with loop trip counts folded in (see launch/dryrun.py).

Convention notes (documented in EXPERIMENTS.md):
  * dot FLOPs are per-device sums x chips — symmetric SPMD makes this the
    global count; it EXCLUDES elementwise flops (negligible next to dots).
  * HBM traffic counts result bytes of top-level (non-fused) ops — fusion
    internals stay in VMEM/registers. An approximation; used for term
    comparison, not absolute bandwidth claims.
  * collective term uses per-device payload bytes over one 50 GB/s link —
    the pessimistic single-link view (no axis-parallel link overlap).
"""
from __future__ import annotations

import json
import os
from typing import Any

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link (ICI)
CHIPS = {"single": 256, "multi": 512}

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

_LEVERS = {
    "compute": "raise per-chip utilization: larger microbatch or fewer remat recomputes",
    "memory": "cut HBM reads: fuse attention (flash kernel), wider tiles, bf16 buffers",
    "collective": "shrink payloads: overlap FSDP all-gathers with compute, gradient compression, TP-block fusion",
}


def tokens_of(shape_name: str, rec: dict) -> int:
    from repro.configs import SHAPES

    s = SHAPES[shape_name]
    if rec.get("kind") == "decode":
        return s.global_batch  # one token per sequence
    return s.global_batch * s.seq_len


def analyze_record(rec: dict[str, Any]) -> dict[str, Any] | None:
    if rec.get("status") != "ok":
        return None
    chips = CHIPS[rec["mesh"]]
    flops_global = rec.get("dot_flops_per_device", 0) * chips
    hbm_global = rec.get("hbm_traffic_per_device", 0) * chips
    coll_dev = rec.get("collectives", {}).get("total_bytes", 0)
    t_compute = flops_global / (chips * PEAK_FLOPS)
    t_memory = hbm_global / (chips * HBM_BW)
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    d_tokens = tokens_of(rec["shape"], rec)
    n_active = rec.get("active_params", rec.get("params", 0))
    model_flops = 6 * n_active * d_tokens
    if rec.get("kind") in ("prefill", "decode"):
        model_flops = 2 * n_active * d_tokens  # forward only
    useful = model_flops / flops_global if flops_global else 0.0
    bound = max(terms.values())
    roofline_frac = (flops_global / (chips * PEAK_FLOPS)) / bound if bound else 0.0
    return {
        "cell": rec["cell"],
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops": flops_global,
        "useful_ratio": useful,
        "roofline_fraction": roofline_frac,
        "lever": _LEVERS[dominant],
        "microbatches": rec.get("microbatches"),
    }


def load_all(results_dir: str | None = None, mesh: str = "single") -> list[dict]:
    d = os.path.abspath(results_dir or RESULTS_DIR)
    out = []
    if not os.path.isdir(d):
        return out
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json") or f"__{mesh}" not in name:
            continue
        with open(os.path.join(d, name)) as f:
            rec = json.load(f)
        a = analyze_record(rec)
        if a:
            out.append(a)
    return out


def run(quick=True) -> list[tuple[str, float, str]]:
    rows = []
    for a in load_all():
        rows.append((
            f"roofline_{a['arch']}_{a['shape']}",
            a["roofline_fraction"],
            f"dom={a['dominant']} tc={a['t_compute_s']:.2e}s tm={a['t_memory_s']:.2e}s "
            f"tx={a['t_collective_s']:.2e}s useful={a['useful_ratio']:.2f}",
        ))
    if not rows:
        rows.append(("roofline_missing", 0.0, "run: python -m repro.launch.dryrun --all"))
    return rows


def markdown_table(mesh: str = "single") -> str:
    rows = load_all(mesh=mesh)
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | dominant | "
           "MODEL_FLOPS | HLO_FLOPs | useful | roofline frac | lever |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for a in rows:
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3e} | {a['t_memory_s']:.3e} "
            f"| {a['t_collective_s']:.3e} | **{a['dominant']}** | {a['model_flops']:.2e} "
            f"| {a['hlo_flops']:.2e} | {a['useful_ratio']:.2f} | {a['roofline_fraction']:.2f} "
            f"| {a['lever']} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
