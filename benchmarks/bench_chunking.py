"""Paper Table II ablation: T1-T4 chunk/sort composition under the
multi-resource simulator — visits, makespan, idle fraction per strategy.

The paper argues T4 (skip-mod chunk -> per-chunk traversal sort) dominates:
T1/T3 leave resources idle after prunes (contiguous blocks), in-order never
prunes ahead.
"""
from __future__ import annotations

import numpy as np

from repro.core import SimulatedScheduler, make_space


def run(quick=True) -> list[tuple[str, float, str]]:
    rows = []
    k0s = (10, 24, 40, 55) if not quick else (24, 48)
    for strategy in ("T1", "T2", "T3", "T4"):
        mk, vis, idle = [], [], []
        for k0 in k0s:
            space = make_space((2, 60), 0.7, 0.2)
            sched = SimulatedScheduler(space, 4, order="pre", strategy=strategy)
            tr = sched.run(lambda k: 1.0 if k <= k0 else 0.0)
            assert tr.k_optimal == k0, (strategy, k0, tr.k_optimal)
            mk.append(tr.makespan)
            vis.append(tr.visit_fraction * 100)
            idle.append(1.0 - tr.busy_time / (tr.makespan * tr.num_resources))
        rows.append((
            f"chunking_{strategy}",
            float(np.mean(vis)),
            f"pct_visited avg; makespan={np.mean(mk):.1f} idle_frac={np.mean(idle):.2f}",
        ))
    # in-order baseline (the degenerate linear order)
    space = make_space((2, 60), 0.7)
    tr = SimulatedScheduler(space, 4, order="in", strategy="T4").run(
        lambda k: 1.0 if k <= 48 else 0.0
    )
    rows.append(("chunking_inorder_T4", tr.visit_fraction * 100,
                 f"pct_visited; makespan={tr.makespan:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
