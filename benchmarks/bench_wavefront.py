"""Per-k threads vs batched wavefronts: visits, makespan, compile counts.

The thread path (paper Alg 3/4 on one device) pays one jit trace per
distinct k it visits — ``nmfk_score`` is compiled with static k — plus
Python-thread contention for the single device. The wavefront path fits a
whole frontier as one mask-padded vmapped NMFk at a fixed ``k_pad``, so the
number of compilations is the number of distinct padded batch shapes
(a handful, by power-of-two bucketing) regardless of |K|.

Compile counts are reported as deterministic static-shape counts:
  threads  -> number of distinct k values evaluated (one trace each)
  batched  -> len(plane.shapes_compiled)

  PYTHONPATH=src python benchmarks/bench_wavefront.py --k-max 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core import ThreadPoolScheduler, WavefrontScheduler, make_space
from repro.factorization.nmfk import make_nmfk_evaluator
from repro.factorization.planes import NMFkBatchPlane
from repro.factorization.synthetic import nmf_data


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--m", type=int, default=72)
    ap.add_argument("--k-true", type=int, default=5)
    ap.add_argument("--k-min", type=int, default=2)
    ap.add_argument("--k-max", type=int, default=16)
    ap.add_argument("--threshold", type=float, default=0.9)
    ap.add_argument("--resources", type=int, default=4)
    ap.add_argument("--n-perturbs", type=int, default=4)
    ap.add_argument("--nmf-iters", type=int, default=100)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(0)
    v, _, _ = nmf_data(key, n=args.n, m=args.m, k_true=args.k_true)
    space = make_space((args.k_min, args.k_max), args.threshold)

    # -- per-k thread path ---------------------------------------------------
    evaluate = make_nmfk_evaluator(v, key, n_perturbs=args.n_perturbs, nmf_iters=args.nmf_iters)
    t0 = time.time()
    res_t = ThreadPoolScheduler(space, args.resources).run(evaluate)
    t_threads = time.time() - t0
    compiles_threads = len(set(res_t.visited_ks))  # static k -> one trace each

    # -- batched wavefront path ----------------------------------------------
    plane = NMFkBatchPlane(
        v, key, n_perturbs=args.n_perturbs, nmf_iters=args.nmf_iters, k_pad=args.k_max
    )
    sched = WavefrontScheduler(space)
    t0 = time.time()
    res_b = sched.run(plane)
    t_batched = time.time() - t0

    out = {
        "n_candidates": len(space.ks),
        "threads": {
            "k_optimal": res_t.k_optimal,
            "n_visited": res_t.n_visited,
            "seconds": round(t_threads, 2),
            "jit_compiles": compiles_threads,
            "resources": args.resources,
        },
        "batched": {
            "k_optimal": res_b.k_optimal,
            "n_visited": res_b.n_visited,
            "seconds": round(t_batched, 2),
            "jit_compiles": len(plane.shapes_compiled),
            "waves": sched.n_dispatches,
            "compiled_shapes": sorted(plane.shapes_compiled),
        },
        "speedup": round(t_threads / max(t_batched, 1e-9), 2),
        "agree": res_t.k_optimal == res_b.k_optimal,
    }
    if not args.quiet:
        print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    run()
