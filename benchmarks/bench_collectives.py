"""Pipelined ring collectives: sweep throughput + overlap vs the sync fit.

Acceptance bench for the decomposed-psum MU schedule in
``repro.factorization.distributed``: under 8 virtual CPU devices, run the
same data-sharded NMF fit through both communication schedules and report

  * ``collectives_ring_rel_err`` — ``ring_psum`` (psum_scatter + ring
    all-gather, non-divisible leading dim exercising the pad path) vs
    ``lax.psum`` on the 8-way mesh,
  * ``collectives_sweep_{sync,pipelined}_us`` — measured per-sweep wall
    time of ``distributed_nmf`` under each schedule (the 8 "devices"
    timeshare one core, so this measures schedule overhead, not overlap —
    the pipelined path must not regress it),
  * ``collectives_throughput_ratio`` — sync/pipelined sweep time (>= ~1
    means the decomposed schedule costs nothing even where it cannot win),
  * ``collectives_pipe_rel_err_gap`` — |rel_error difference| of the two
    schedules' fits (the one-sweep-stale staleness bound),
  * ``collectives_overlap_fraction`` / ``collectives_modeled_speedup`` —
    ``overlap_model``'s per-sweep comm-hiding fraction and pipelined-vs-
    sync speedup at the bench shape (the quantity real interconnects
    realize; also published as an ``overlap_fraction`` gauge so the BENCH
    json ``_meta.metrics`` block records it).

Needs 8 XLA devices, so it re-execs itself as a child process with
``--xla_force_host_platform_device_count=8`` (the flag must precede jax
init) and parses one JSON line back — same scaffolding as
``bench_sharded``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD_FLAG = "--child"


def _child_main(full: bool) -> dict:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.factorization.distributed import (
        distributed_nmf,
        overlap_model,
        ring_psum,
        shard_map,
    )

    devs = jax.devices()
    p = min(8, len(devs))
    mesh = jax.make_mesh((p,), ("data",), devices=devs[:p])
    key = jax.random.PRNGKey(0)

    # --- ring_psum vs lax.psum parity (lead=13 is not divisible by 8) ------
    x = jax.random.normal(key, (p * 4, 13, 33))

    def _reduce(fn):
        f = shard_map(
            lambda xl: fn(xl.reshape(-1, 33)), mesh,
            in_specs=(P("data"),), out_specs=P(), check_rep=False,
        )
        return jax.jit(f)(x)

    ref = _reduce(lambda v: jax.lax.psum(v, "data"))
    got = _reduce(lambda v: ring_psum(v, "data", p))
    ring_rel_err = float(
        jnp.max(jnp.abs(got - ref)) / jnp.maximum(jnp.max(jnp.abs(ref)), 1e-12)
    )

    # --- measured sweep throughput, sync vs pipelined ----------------------
    n, m, k = (512, 192, 12) if full else (256, 96, 8)
    iters = 100 if full else 60
    v = jax.random.uniform(jax.random.fold_in(key, 1), (n, m))

    sweep_us = {}
    errs = {}
    for comm in ("sync", "pipelined"):
        distributed_nmf(v, k, key, mesh, iters=iters, comm=comm)  # compile
        reps = 5
        t0 = time.perf_counter()
        for _ in range(reps):
            res = distributed_nmf(v, k, key, mesh, iters=iters, comm=comm)
            jax.block_until_ready(res.w)
        sweep_us[comm] = (time.perf_counter() - t0) / reps / iters * 1e6
        errs[comm] = float(res.rel_error)

    model = overlap_model(n, m, k, p)
    return {
        "ring_rel_err": ring_rel_err,
        "sweep_sync_us": sweep_us["sync"],
        "sweep_pipelined_us": sweep_us["pipelined"],
        "throughput_ratio": sweep_us["sync"] / sweep_us["pipelined"],
        "err_sync": errs["sync"],
        "err_pipelined": errs["pipelined"],
        "err_gap": abs(errs["sync"] - errs["pipelined"]),
        "overlap_fraction": model["overlap_fraction"],
        "comm_fraction": model["comm_fraction"],
        "modeled_speedup": model["speedup"],
        "shape": [n, m, k],
        "data_shards": p,
        "iters": iters,
    }


def _spawn_child(full: bool) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo_root, "src"), env.get("PYTHONPATH")) if p
    )
    cmd = [sys.executable, "-m", "benchmarks.bench_collectives", _CHILD_FLAG]
    if full:
        cmd.append("--full")
    proc = subprocess.run(
        cmd, capture_output=True, text=True, cwd=repo_root, env=env, timeout=1800
    )
    if proc.returncode != 0:
        raise RuntimeError(f"collectives bench child failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(quick=True) -> list[tuple[str, float, str]]:
    from repro.obs import get_metrics

    r = _spawn_child(full=not quick)
    # gauge set in the parent (the child's registry dies with it) so the
    # harness's _meta.metrics block records the run's overlap fraction
    get_metrics().set_gauge("overlap_fraction", r["overlap_fraction"])
    n, m, k = r["shape"]
    return [
        (
            "collectives_ring_rel_err",
            r["ring_rel_err"],
            f"ring psum_scatter+gather vs lax.psum, {r['data_shards']} shards "
            "(non-divisible lead exercises padding)",
        ),
        (
            "collectives_sweep_sync_us",
            r["sweep_sync_us"],
            f"measured us/sweep, blocking Gram psums (n={n} m={m} k={k}, "
            f"{r['data_shards']} virtual shards timesharing one core)",
        ),
        (
            "collectives_sweep_pipelined_us",
            r["sweep_pipelined_us"],
            "measured us/sweep, fused scatter+gather with overlapped W-update",
        ),
        (
            "collectives_throughput_ratio",
            r["throughput_ratio"],
            "sync/pipelined sweep time: >= ~1 means no schedule-overhead "
            "regression even where virtual devices cannot overlap",
        ),
        (
            "collectives_pipe_rel_err_gap",
            r["err_gap"],
            f"|rel_error gap| of one-sweep-stale vs sync fit "
            f"(sync {r['err_sync']:.4f}, pipelined {r['err_pipelined']:.4f})",
        ),
        (
            "collectives_overlap_fraction",
            r["overlap_fraction"],
            f"modeled share of per-sweep Gram comm hidden behind the local "
            f"W-update (comm is {r['comm_fraction'] * 100:.1f}% of a sync sweep)",
        ),
        (
            "collectives_modeled_speedup",
            r["modeled_speedup"],
            "modeled pipelined-vs-sync sweep speedup on a balanced interconnect",
        ),
    ]


if __name__ == "__main__":
    if _CHILD_FLAG in sys.argv:
        print(json.dumps(_child_main(full="--full" in sys.argv)))
    else:
        for row in run():
            print(row)
