"""Paper §IV-A K-Means RMSE table: accuracy of recovered k vs k_true.

Paper: Post-ES 1.08, Pre-ES 2.11, Post-Vanilla 1.08, Pre-Vanilla 1.72,
Standard 1.32 (stochastic scoring, 50 restarts). We regenerate at reduced
scale with median-of-3 restarts.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import binary_bleed_worklist, make_space, standard_search
from repro.core.scoring import davies_bouldin_score
from repro.factorization import blob_data, kmeans

K_RANGE = (2, 20)
DB_SELECT, DB_STOP = 0.75, 1.5


def _curve(key, kt, d=8, repeats=3):
    n = max(280, 24 * kt)  # keep per-cluster support as k_true grows
    x, _ = blob_data(key, n=n, d=d, k_true=kt, std=0.5, spread=9.0)
    out = {}
    for k in range(K_RANGE[0], K_RANGE[1] + 1):
        vals = [
            float(davies_bouldin_score(x, kmeans(x, k, jax.random.fold_in(key, 7 * k + r)).labels, k))
            for r in range(repeats)
        ]
        out[k] = float(np.median(vals))
    return out


def run(k_trues=(3, 5, 7, 9, 11, 13), quick=True) -> list[tuple[str, float, str]]:
    if quick:
        k_trues = (3, 6, 9, 12)
    key = jax.random.PRNGKey(5)
    found = {"pre_vanilla": [], "post_vanilla": [], "pre_es": [], "post_es": [], "standard": []}
    for kt in k_trues:
        curve = _curve(jax.random.fold_in(key, kt), kt)
        ev = lambda k: curve[k]
        for name, order, stop in (
            ("pre_vanilla", "pre", None), ("post_vanilla", "post", None),
            ("pre_es", "pre", DB_STOP), ("post_es", "post", DB_STOP),
        ):
            space = make_space(K_RANGE, DB_SELECT, stop, "minimize")
            res = binary_bleed_worklist(space, ev, order=order)
            found[name].append(res.best_effort_k("minimize") or 0)
        res = standard_search(make_space(K_RANGE, DB_SELECT, None, "minimize"), ev)
        found["standard"].append(res.best_effort_k("minimize") or 0)

    rows = []
    for name, ks in found.items():
        rmse = float(np.sqrt(np.mean((np.array(ks) - np.array(k_trues)) ** 2)))
        rows.append((f"kmeans_rmse_{name}", rmse, f"found={ks} true={list(k_trues)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
