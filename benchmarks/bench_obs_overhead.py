"""Tracing overhead on the wavefront search path (<3% budget).

The observability layer's contract: with the default ``NullTracer`` the
hot path pays one attribute read per potential span (~0%); with a real
``Tracer`` installed the cost is a handful of dict appends per wave —
invisible next to the model fits it brackets. This bench measures both on
the same wavefront NMFk workload as ``bench_wavefront``:

  obs/null_seconds    best-of-N wall-clock, NullTracer (default)
  obs/traced_seconds  best-of-N wall-clock, Tracer + fresh Metrics
  obs/overhead_pct    100 * (traced - null) / null  — must be < 3
  obs/trace_events    records buffered by the traced run

A warm-up run (untimed) populates the jit cache first so the comparison
is pure steady-state dispatch, not compilation luck.

  PYTHONPATH=src python -m benchmarks.bench_obs_overhead
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core import WavefrontScheduler, make_space
from repro.factorization.planes import NMFkBatchPlane
from repro.factorization.synthetic import nmf_data
from repro.obs import NULL_TRACER, Metrics, Tracer, use_metrics, use_tracer


def _search_once(v, key, space, n_perturbs, nmf_iters, tracer):
    metrics = Metrics()
    with use_tracer(tracer), use_metrics(metrics):
        plane = NMFkBatchPlane(
            v, key, n_perturbs=n_perturbs, nmf_iters=nmf_iters, k_pad=max(space.ks)
        )
        sched = WavefrontScheduler(space)
        t0 = time.perf_counter()
        result = sched.run(plane)
        dt = time.perf_counter() - t0
    return dt, result, metrics


def run(quick: bool = True, repeats: int = 3):
    n, m = (48, 56) if quick else (96, 104)
    nmf_iters = 60 if quick else 150
    n_perturbs = 3 if quick else 4
    key = jax.random.PRNGKey(0)
    v, _, _ = nmf_data(key, n=n, m=m, k_true=5)
    space = make_space((2, 16), 0.9)

    _search_once(v, key, space, n_perturbs, nmf_iters, NULL_TRACER)  # warm jit cache

    null_times, traced_times = [], []
    traced_events = 0
    k_null = k_traced = None
    for _ in range(repeats):
        dt, res, _ = _search_once(v, key, space, n_perturbs, nmf_iters, NULL_TRACER)
        null_times.append(dt)
        k_null = res.k_optimal
        tracer = Tracer()
        dt, res, _ = _search_once(v, key, space, n_perturbs, nmf_iters, tracer)
        traced_times.append(dt)
        traced_events = len(tracer.events())
        k_traced = res.k_optimal

    t_null = min(null_times)
    t_traced = min(traced_times)
    overhead_pct = 100.0 * (t_traced - t_null) / max(t_null, 1e-9)
    yield "obs/null_seconds", t_null, f"k_opt={k_null}"
    yield "obs/traced_seconds", t_traced, f"k_opt={k_traced}"
    yield "obs/overhead_pct", overhead_pct, "budget <3%"
    yield "obs/trace_events", float(traced_events), "records buffered"


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    out = {}
    for name, value, derived in run(quick=not args.full, repeats=args.repeats):
        out[name] = value
        print(f"{name},{value:.4f},{derived}")
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
