"""Paper Fig 7/8: % of K visited — NMFk and K-Means, Vanilla vs Early Stop,
pre- vs post-order. Reduced-scale regeneration of the paper's synthetic
experiment (visit fractions depend on score *shape*, not matrix size; the
paper's 1000x1100 matrices only change T_model).

Paper reference numbers (single-node, K=2..30):
  NMFk   : pre/vanilla 56%, post/vanilla 76%, pre/ES 27%, post/ES 44%
  K-Means: pre/vanilla 77%, post/vanilla 92%, pre/ES 50%, post/ES 71%
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import binary_bleed_worklist, make_space
from repro.core.scoring import davies_bouldin_score
from repro.factorization import blob_data, kmeans, nmf_data, nmfk_score

K_RANGE = (2, 30)
# Thresholds calibrated to the synthetic curves the same way the paper's
# t_W/t_H are chosen per domain: sub-optimal k must SELECT (the paper's
# assumption is "score increases with k for all sub-optimal k" and its
# pruning needs sub-k crossings), overfit k must STOP.
SELECT_T = 0.55
STOP_T = 0.05
# K-Means DB (minimization): select when DB <= 0.75, stop when DB >= 1.5
DB_SELECT, DB_STOP = 0.75, 1.5


def _visit_pct(curve: dict[int, float], mode: str, select_t, stop_t, order) -> tuple[float, int | None]:
    space = make_space(K_RANGE, select_t, stop_t, mode)
    res = binary_bleed_worklist(space, lambda k: curve[k], order=order)
    return res.visit_fraction * 100.0, res.k_optimal


def nmfk_curves(k_trues, n_perturbs=3, iters=80):
    key = jax.random.PRNGKey(0)
    curves = {}
    for kt in k_trues:
        # scale the matrix with k_true so every planted component keeps
        # enough rows for a stable silhouette (paper: 1000x1100 for k<=30)
        n = max(240, 28 * kt)
        m = n + 20
        v, _, _ = nmf_data(jax.random.fold_in(key, kt), n=n, m=m, k_true=kt)
        curve = {}
        for k in range(K_RANGE[0], K_RANGE[1] + 1):
            sc = nmfk_score(v, k, jax.random.fold_in(key, 1000 + k), n_perturbs=n_perturbs,
                            nmf_iters=iters)
            curve[k] = float(sc.min_silhouette)
        curves[kt] = curve
    return curves


def kmeans_curves(k_trues, d=8, repeats=3):
    key = jax.random.PRNGKey(1)
    curves = {}
    for kt in k_trues:
        n = max(280, 24 * kt)
        x, _ = blob_data(jax.random.fold_in(key, kt), n=n, d=d, k_true=kt, std=0.5, spread=9.0)
        curve = {}
        for k in range(K_RANGE[0], K_RANGE[1] + 1):
            vals = []
            for r in range(repeats):
                res = kmeans(x, k, jax.random.fold_in(key, 97 * k + r))
                vals.append(float(davies_bouldin_score(x, res.labels, k)))
            curve[k] = float(np.median(vals))
        curves[kt] = curve
    return curves


def run(k_trues=(3, 6, 9, 12, 15, 18, 21, 24, 27), quick=True) -> list[tuple[str, float, str]]:
    if quick:
        k_trues = (4, 8, 14, 20)
    rows = []
    for algo, curves, mode, sel, stop in (
        ("nmfk", nmfk_curves(k_trues), "maximize", SELECT_T, STOP_T),
        ("kmeans", kmeans_curves(k_trues), "minimize", DB_SELECT, DB_STOP),
    ):
        for variant, stop_t in (("vanilla", None), ("earlystop", stop)):
            for order in ("pre", "post"):
                pcts, correct = [], 0
                for kt, curve in curves.items():
                    pct, k_opt = _visit_pct(curve, mode, sel, stop_t, order)
                    pcts.append(pct)
                    correct += int(k_opt == kt)
                rows.append((
                    f"visits_{algo}_{order}_{variant}",
                    float(np.mean(pcts)),
                    f"pct_visited avg over k_true={list(curves)}; correct {correct}/{len(curves)}",
                ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
