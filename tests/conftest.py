"""Shared test configuration.

Installs a minimal ``hypothesis`` fallback stub when the real package is
absent, so the property-test modules collect and run from a clean checkout
(the real hypothesis ships in the ``dev`` extra and is preferred — the stub
degrades ``@given`` to deterministic seeded random sampling with no
shrinking).
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    _MAX_EXAMPLES_CAP = 30  # stub has no shrinking; keep sampling cheap

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: None)

    class _DataObject:
        """Stand-in for hypothesis's interactive ``data`` fixture."""

        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    def _just(value):
        return _Strategy(lambda rng: value)

    def _none():
        return _just(None)

    def _one_of(*options):
        opts = list(options)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))].example(rng))

    def _lists(elements, min_size=0, max_size=10, unique=False):
        def draw(rng):
            size = rng.randint(min_size, max_size)
            if not unique:
                return [elements.example(rng) for _ in range(size)]
            out, seen, attempts = [], set(), 0
            while len(out) < size and attempts < 50 * max(size, 1):
                v = elements.example(rng)
                attempts += 1
                if v not in seen:
                    seen.add(v)
                    out.append(v)
            return out

        return _Strategy(draw)

    def _permutations(seq):
        items = list(seq)
        return _Strategy(lambda rng: rng.sample(items, len(items)))

    def _builds(target, **kwargs):
        return _Strategy(
            lambda rng: target(**{name: s.example(rng) for name, s in kwargs.items()})
        )

    def _data():
        return _DataStrategy()

    def _settings(max_examples=20, deadline=None, **_ignored):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def _given(*args, **strategies):
        if args:
            raise TypeError("hypothesis stub supports keyword strategies only")

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*wargs, **wkwargs):
                n = min(getattr(fn, "_stub_max_examples", 20), _MAX_EXAMPLES_CAP)
                seed0 = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
                for i in range(n):
                    rng = random.Random(seed0 + i)
                    drawn = {}
                    for name, strat in strategies.items():
                        if isinstance(strat, _DataStrategy):
                            drawn[name] = _DataObject(rng)
                        else:
                            drawn[name] = strat.example(rng)
                    fn(*wargs, **wkwargs, **drawn)

            # Hide the strategy-filled params from pytest so it doesn't treat
            # them as fixtures (hypothesis does the same signature surgery).
            sig = inspect.signature(fn)
            params = [p for p in sig.parameters.values() if p.name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.just = _just
    _st.none = _none
    _st.one_of = _one_of
    _st.lists = _lists
    _st.permutations = _permutations
    _st.builds = _builds
    _st.data = _data

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
