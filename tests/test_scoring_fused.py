"""Fused streaming silhouette scorer vs the dense jnp oracle.

Parity across all three dispatch tiers of ``cluster_dist_sums`` (dense jnp /
blocked jnp / Pallas), 2-D and batched, masked and unmasked, singleton and
empty clusters, non-tile-aligned n/d — plus a hypothesis property test that
the streaming and dense silhouette agree within fp32 tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import scoring
from repro.core.scoring import (
    cluster_dist_sums,
    silhouette_samples_masked,
    silhouette_score,
    silhouette_score_masked,
)
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)
TOL = dict(rtol=1e-4, atol=1e-4)


def _problem(seed: int, shape: tuple, k: int):
    kx, kl = jax.random.split(jax.random.fold_in(KEY, seed))
    x = jax.random.normal(kx, shape)
    labels = jax.random.randint(kl, shape[:-1], 0, k)
    return x, labels


# -----------------------------------------------------------------------------
# Pallas kernel vs dense oracle
# -----------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n,m,d,k",
    [
        (32, 32, 5, 3),      # tiny, nothing aligned
        (70, 70, 17, 6),     # non-tile-aligned n and d
        (128, 128, 128, 4),  # fully 128-aligned tiles
        (40, 24, 9, 5),      # rectangular (x vs separate y rows)
        (8, 8, 200, 2),      # d-reduction dominates
    ],
)
def test_kernel_matches_oracle_2d(n, m, d, k):
    x, _ = _problem(n * m + d, (n, d), k)
    y, labels = _problem(n * m + d + 1, (m, d), k)
    onehot = jax.nn.one_hot(labels, k)
    got = ops.silhouette_dist_sums(x, onehot, y)
    want = ref.silhouette_dist_sums_ref(x, onehot, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("b,n,d,k", [(3, 32, 5, 3), (2, 70, 17, 6), (4, 24, 9, 2)])
@pytest.mark.parametrize("masked", [False, True])
def test_kernel_matches_oracle_batched(b, n, d, k, masked):
    x, labels = _problem(b * n + d, (b, n, d), k)
    onehot = jax.nn.one_hot(labels, k)
    if masked:  # zero one-hot rows = masked points; must contract to nothing
        onehot = onehot.at[:, -5:, :].set(0.0)
    got = ops.silhouette_dist_sums_batched(x, onehot)
    want = ref.silhouette_dist_sums_ref(x, onehot)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# -----------------------------------------------------------------------------
# Blocked jnp tier vs dense tier
# -----------------------------------------------------------------------------
@pytest.mark.parametrize("n,block_rows", [(60, 16), (64, 16), (37, 8), (50, 64)])
def test_blocked_tier_matches_dense(n, block_rows):
    x, labels = _problem(n + block_rows, (n, 6), 4)
    onehot = jax.nn.one_hot(labels, 4)
    want = cluster_dist_sums(x, onehot)
    got = cluster_dist_sums(x, onehot, block_rows=block_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_blocked_tier_batched_and_broadcast():
    """Batched one-hot against both batched and shared (unbatched) x."""
    b, n, d, k = 3, 45, 5, 4
    x, labels = _problem(b * n, (b, n, d), k)
    onehot = jax.nn.one_hot(labels, k)
    np.testing.assert_allclose(
        np.asarray(cluster_dist_sums(x, onehot, block_rows=16)),
        np.asarray(cluster_dist_sums(x, onehot)),
        **TOL,
    )
    x2 = x[0]  # shared points, per-lane labels — the KMeansBatchPlane shape
    want = jnp.matmul(jnp.sqrt(scoring.pairwise_sq_dists(x2)), onehot)
    np.testing.assert_allclose(
        np.asarray(cluster_dist_sums(x2, onehot, block_rows=16)), np.asarray(want), **TOL
    )
    np.testing.assert_allclose(
        np.asarray(cluster_dist_sums(x2, onehot, use_kernel=True)), np.asarray(want), **TOL
    )


def test_auto_dispatch_picks_blocked_past_dense_ceiling(monkeypatch):
    """Above _DENSE_MAX_ELEMENTS the auto tier must row-block, same result."""
    x, labels = _problem(99, (48, 5), 3)
    onehot = jax.nn.one_hot(labels, 3)
    want = cluster_dist_sums(x, onehot)
    monkeypatch.setattr(scoring, "_DENSE_MAX_ELEMENTS", 0)
    got = cluster_dist_sums(x, onehot)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# -----------------------------------------------------------------------------
# Full silhouette through the fused path
# -----------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,k", [(30, 4, 3), (60, 6, 5), (70, 17, 4)])
def test_silhouette_kernel_matches_dense(n, d, k):
    x, labels = _problem(n * d, (n, d), k)
    got = float(silhouette_score(x, labels, k, use_kernel=True))
    want = float(silhouette_score(x, labels, k))
    assert abs(got - want) <= 1e-4 * max(1.0, abs(want))


def test_silhouette_kernel_singleton_and_empty_clusters():
    """Cluster k-1 empty, cluster 0 a singleton — conventions must survive
    the streaming contraction (s=0 for singletons, empties out of b(i))."""
    n, d, k = 40, 5, 5
    x, _ = _problem(7, (n, d), k)
    labels = jnp.concatenate([jnp.zeros(1, jnp.int32), 1 + (jnp.arange(n - 1) % (k - 2))])
    assert int(jnp.sum(labels == 0)) == 1 and int(jnp.sum(labels == k - 1)) == 0
    want = silhouette_samples_masked(x, labels, k)
    got = silhouette_samples_masked(x, labels, k, use_kernel=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    assert float(got[0]) == 0.0  # singleton convention


@pytest.mark.parametrize("use_kernel", [False, True])
def test_silhouette_masked_batched_shared_x(use_kernel):
    """The KMeansBatchPlane call shape: x (n, d), labels (b, n), point_mask
    (b, n) — per-lane masked scores must match per-lane dense scoring."""
    b, n, d, k = 3, 36, 4, 4
    x, _ = _problem(11, (n, d), k)
    _, labels = _problem(13, (b, n, d), k)
    n_act = jnp.asarray([n, n - 6, n - 11])
    point_mask = jnp.arange(n)[None, :] < n_act[:, None]
    got = silhouette_score_masked(x, labels, k, point_mask=point_mask, use_kernel=use_kernel)
    for lane in range(b):
        na = int(n_act[lane])
        want = float(silhouette_score(x[:na], labels[lane, :na], k))
        assert abs(float(got[lane]) - want) <= 2e-4, (lane, float(got[lane]), want)


def test_nmfk_pooled_scoring_kernel_parity():
    """use_kernel reaches the pooled-column scorer (incl. under vmap)."""
    from repro.factorization import nmf_data
    from repro.factorization.nmfk import nmfk_score, nmfk_score_batched

    v, _, _ = nmf_data(KEY, n=48, m=40, k_true=3)
    a = nmfk_score(v, 3, KEY, n_perturbs=3, nmf_iters=25)
    b = nmfk_score(v, 3, KEY, n_perturbs=3, nmf_iters=25, use_kernel=True)
    np.testing.assert_allclose(float(a.min_silhouette), float(b.min_silhouette), rtol=1e-3, atol=1e-4)
    sa = nmfk_score_batched(v, [2, 3], KEY, k_pad=4, n_perturbs=3, nmf_iters=25)
    sb = nmfk_score_batched(v, [2, 3], KEY, k_pad=4, n_perturbs=3, nmf_iters=25, use_kernel=True)
    np.testing.assert_allclose(
        np.asarray(sa.min_silhouette), np.asarray(sb.min_silhouette), rtol=1e-3, atol=1e-4
    )


def test_kmeans_plane_kernel_parity():
    from repro.factorization.planes import KMeansBatchPlane

    x, _ = _problem(17, (40, 5), 4)
    ref_scores = KMeansBatchPlane(x, KEY, score="silhouette", k_pad=5).evaluate_batch([2, 4])
    ker_scores = KMeansBatchPlane(
        x, KEY, score="silhouette", k_pad=5, use_kernel=True
    ).evaluate_batch([2, 4])
    np.testing.assert_allclose(ref_scores, ker_scores, rtol=1e-4, atol=1e-4)


# -----------------------------------------------------------------------------
# Property test: streaming silhouette == dense silhouette (fp32 tolerance)
# -----------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=8, max_value=90),
    d=st.integers(min_value=1, max_value=20),
    k=st.integers(min_value=2, max_value=7),
    tier=st.sampled_from(["blocked", "kernel"]),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_streaming_silhouette_matches_dense_property(n, d, k, tier, seed):
    x, labels = _problem(seed, (n, d), k)
    want = float(silhouette_score(x, labels, k))
    if tier == "kernel":
        got = float(silhouette_score(x, labels, k, use_kernel=True))
    else:
        # un-jitted body so the monkeypatched ceiling takes effect (the jit
        # cache would otherwise replay a dense-tier trace for a seen shape)
        orig = scoring._DENSE_MAX_ELEMENTS
        scoring._DENSE_MAX_ELEMENTS = 0
        try:
            got = float(silhouette_score.__wrapped__(x, labels, k))
        finally:
            scoring._DENSE_MAX_ELEMENTS = orig
    assert abs(got - want) <= 1e-4 * max(1.0, abs(want)), (n, d, k, tier, got, want)
