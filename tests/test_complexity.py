"""§III-A empirical complexity: Θ(n^log2(p+1)) between log n (p=0) and n (p=1).

For a pure square wave at k0=n (always selecting, never stopping), every
midpoint selects: one recursion direction survives -> visits ~ log2(n) + the
upward bleed tail. For k0 in the middle with no early-stop, both directions
stay live above k0 -> visits grow like the number of k > k0 plus log terms.
"""
import math

from repro.core import binary_bleed_worklist, make_space


def visits(n, k0, stop=None):
    space = make_space((1, n), 0.7, stop)
    res = binary_bleed_worklist(space, lambda k: 1.0 if k <= k0 else 0.0, order="pre")
    assert res.k_optimal == k0
    return res.n_visited


def test_best_case_logarithmic():
    """k0 = n: every visit selects and prunes below — pure binary descent."""
    for n in (64, 256, 1024, 4096):
        v = visits(n, n)
        assert v <= 2 * math.log2(n) + 4, (n, v)


def test_scaling_exponent_below_linear():
    """Fit visits ~ c*n^alpha over doublings; alpha must be < 1 (sub-linear)
    for the square wave at k0 = n/2 with early stop."""
    ns = [128, 256, 512, 1024, 2048]
    vs = [visits(n, n // 2, stop=0.2) for n in ns]
    alphas = [
        math.log(vs[i + 1] / vs[i]) / math.log(ns[i + 1] / ns[i]) for i in range(len(ns) - 1)
    ]
    assert max(alphas) < 0.8, (vs, alphas)


def test_worst_case_still_linear_bound():
    """Never-selecting scores: every k is visited at most once (≤ n)."""
    for n in (64, 512):
        space = make_space((1, n), 0.9)
        res = binary_bleed_worklist(space, lambda k: 0.0, order="pre")
        assert res.n_visited <= n


def test_vanilla_vs_earlystop_ordering():
    """Early stop can only reduce visits (paper Fig 8: ES lines below Vanilla)."""
    for n in (64, 256, 1024):
        for k0 in (n // 4, n // 2, 3 * n // 4):
            assert visits(n, k0, stop=0.2) <= visits(n, k0)
