"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


# -----------------------------------------------------------------------------
# NMF MU update
# -----------------------------------------------------------------------------
@pytest.mark.parametrize("n,m,k", [(64, 48, 5), (256, 128, 16), (100, 90, 7), (8, 8, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mu_update_h(n, m, k, dtype):
    kv, kw, kh = jax.random.split(jax.random.fold_in(KEY, n * m + k), 3)
    v = jax.random.uniform(kv, (n, m), dtype)
    w = jax.random.uniform(kw, (n, k), dtype, 0.1, 1.0)
    h = jax.random.uniform(kh, (k, m), dtype, 0.1, 1.0)
    got = ops.mu_update_h(v, w, h)
    want = ref.mu_update_h_ref(v, w, h).astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("n,m,k", [(64, 48, 5), (256, 128, 16), (100, 90, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mu_update_w(n, m, k, dtype):
    kv, kw, kh = jax.random.split(jax.random.fold_in(KEY, n + m + k), 3)
    v = jax.random.uniform(kv, (n, m), dtype)
    w = jax.random.uniform(kw, (n, k), dtype, 0.1, 1.0)
    h = jax.random.uniform(kh, (k, m), dtype, 0.1, 1.0)
    got = ops.mu_update_w(v, w, h)
    want = ref.mu_update_w_ref(v, w, h).astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


def test_mu_update_preserves_zero_rows():
    """Zero-padded factor rows must stay zero through the fused update."""
    v = jax.random.uniform(KEY, (32, 24))
    w = jax.random.uniform(KEY, (32, 4), minval=0.1).at[:, -1].set(0.0)
    h = jax.random.uniform(KEY, (4, 24), minval=0.1)
    got = ops.mu_update_w(v, w, h)
    assert float(jnp.max(jnp.abs(got[:, -1]))) == 0.0


# -----------------------------------------------------------------------------
# pairwise distances
# -----------------------------------------------------------------------------
@pytest.mark.parametrize("n,m,d", [(32, 40, 5), (128, 128, 128), (70, 30, 17), (8, 8, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise(n, m, d, dtype):
    kx, ky = jax.random.split(jax.random.fold_in(KEY, n * m * d))
    x = jax.random.normal(kx, (n, d), dtype)
    y = jax.random.normal(ky, (m, d), dtype)
    got = ops.pairwise_sq_dists(x, y)
    want = ref.pairwise_sq_dists_ref(x, y)
    tol = dict(rtol=5e-2, atol=5e-1) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


# -----------------------------------------------------------------------------
# flash attention
# -----------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,hq,hk,l,d,window",
    [
        (1, 4, 2, 64, 16, None),   # GQA
        (2, 8, 8, 128, 64, None),  # MHA
        (1, 4, 1, 64, 32, 24),     # MQA + sliding window
        (1, 2, 2, 256, 128, None), # 128-aligned tiles
        (1, 14, 2, 64, 64, None),  # qwen-style 7x group
    ],
)
def test_flash_attention(b, hq, hk, l, d, window):
    ks = jax.random.split(jax.random.fold_in(KEY, hq * l + d), 3)
    q = jax.random.normal(ks[0], (b, hq, l, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hk, l, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hk, l, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
    got = ops.flash_attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )


def test_flash_matches_model_sdpa():
    """Kernel agrees with the model's einsum attention path end to end."""
    from repro.models.attention import _sdpa

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 32, 8, 64), jnp.float32)   # (B, L, H, hd)
    k = jax.random.normal(ks[1], (2, 32, 4, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 32, 4, 64), jnp.float32)
    want = _sdpa(q, k, v, causal=True, window=None)
    got = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_kernel_nmf_path_matches_jnp_path():
    from repro.factorization import nmf, nmf_data

    v, _, _ = nmf_data(KEY, n=64, m=48, k_true=4)
    r1 = nmf(v, 4, KEY, iters=25)
    r2 = nmf(v, 4, KEY, iters=25, use_kernel=True)
    np.testing.assert_allclose(np.asarray(r1.w), np.asarray(r2.w), rtol=1e-3, atol=1e-4)
