"""JAX scoring functions vs naive python oracles + §III-D score models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scoring import (
    davies_bouldin_score,
    laplacian_score,
    pairwise_sq_dists,
    silhouette_score,
    square_wave_score,
)


def _naive_silhouette(x, labels, k):
    x = np.asarray(x, np.float64)
    labels = np.asarray(labels)
    n = len(x)
    d = np.sqrt(((x[:, None] - x[None, :]) ** 2).sum(-1))
    s = np.zeros(n)
    for i in range(n):
        own = labels == labels[i]
        if own.sum() <= 1:
            s[i] = 0.0
            continue
        a = d[i][own].sum() / (own.sum() - 1)
        b = np.inf
        for c in range(k):
            if c == labels[i] or not (labels == c).any():
                continue
            b = min(b, d[i][labels == c].mean())
        s[i] = (b - a) / max(a, b)
    return s.mean()


def _naive_db(x, labels, k):
    x = np.asarray(x, np.float64)
    labels = np.asarray(labels)
    cents, scat = [], []
    for c in range(k):
        pts = x[labels == c]
        cents.append(pts.mean(0))
        scat.append(np.sqrt(((pts - pts.mean(0)) ** 2).sum(-1)).mean())
    total = 0.0
    for i in range(k):
        worst = 0.0
        for j in range(k):
            if i == j:
                continue
            m = np.sqrt(((cents[i] - cents[j]) ** 2).sum())
            worst = max(worst, (scat[i] + scat[j]) / m)
        total += worst
    return total / k


@pytest.mark.parametrize("n,d,k", [(30, 4, 3), (60, 6, 5)])
def test_silhouette_matches_naive(n, d, k):
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (n, d))
    labels = jax.random.randint(key, (n,), 0, k)
    got = float(silhouette_score(x, labels, k))
    want = _naive_silhouette(x, labels, k)
    assert abs(got - want) < 2e-4


@pytest.mark.parametrize("n,d,k", [(40, 3, 4), (80, 5, 4)])
def test_davies_bouldin_matches_naive(n, d, k):
    key = jax.random.PRNGKey(n + 1)
    centers = 6.0 * jax.random.normal(jax.random.fold_in(key, 1), (k, d))
    labels = jax.random.randint(key, (n,), 0, k)
    x = centers[labels] + 0.3 * jax.random.normal(key, (n, d))
    got = float(davies_bouldin_score(x, labels, k))
    want = _naive_db(x, labels, k)
    assert abs(got - want) / want < 2e-3


def test_pairwise_nonneg_and_symmetric():
    x = jax.random.normal(jax.random.PRNGKey(0), (25, 7))
    d2 = pairwise_sq_dists(x)
    assert float(jnp.min(d2)) >= 0.0
    np.testing.assert_allclose(d2, d2.T, atol=1e-5)
    np.testing.assert_allclose(jnp.diag(d2), 0.0, atol=1e-4)


def test_square_wave_shape():
    ks = jnp.arange(1, 31)
    s = square_wave_score(ks, 17)
    assert float(s[16]) == 1.0  # k=17 included
    assert float(s[17]) == 0.0  # k=18 off the cliff
    assert bool(jnp.all(s[:17] == 1.0)) and bool(jnp.all(s[17:] == 0.0))


def test_laplacian_peak():
    s = laplacian_score(jnp.arange(1, 31), 10, width=2.0)
    assert int(jnp.argmax(s)) == 9
