"""Mesh-sharded evaluation plane: bucketing, mesh carving, submesh leasing.

The device-heavy parity assertions (sharded vs batched vs the scalar
oracle) need 8 XLA devices, which can only be forced before jax
initializes — they run in a subprocess (``tests/_sharded_child.py``); this
process has a 1-device runtime. Everything shape/policy-level is tested
in-process.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# shape bucketing
# ---------------------------------------------------------------------------
def test_bucket_batch_pow2_and_lane_multiple():
    from repro.factorization.batching import bucket_batch

    assert bucket_batch(1) == 1
    assert bucket_batch(3) == 4
    assert bucket_batch(5) == 8
    # lane floor: every dispatch splits evenly over the mesh
    assert bucket_batch(1, lanes=8, bucket_min=8) == 8
    assert bucket_batch(9, lanes=8, bucket_min=8) == 16
    # non-pow2 lane counts still get lane multiples
    assert bucket_batch(7, lanes=6, bucket_min=6) % 6 == 0


def test_bucket_batch_cap_bounds_padding():
    from repro.factorization.batching import bucket_batch

    assert bucket_batch(3, cap=3) == 3
    # cap never undercuts the dispatch itself
    assert bucket_batch(5, cap=3) == 5
    assert bucket_batch(3, lanes=2, bucket_min=2, cap=3) == 4  # lane multiple wins


def test_bucket_batch_reuses_compiled_shapes():
    from repro.factorization.batching import bucket_batch

    # scalar fallback rides the already-compiled 8-bucket instead of
    # minting a batch-of-one executable
    assert bucket_batch(1, lanes=8, bucket_min=8, compiled=[8, 16]) == 8
    assert bucket_batch(9, lanes=8, bucket_min=8, compiled=[16]) == 16
    # fresh target preferred when it is already compiled
    assert bucket_batch(5, lanes=8, bucket_min=8, compiled=[8, 16]) == 8
    # nothing compiled fits -> fresh target
    assert bucket_batch(9, lanes=8, bucket_min=8, compiled=[8]) == 16
    with pytest.raises(ValueError):
        bucket_batch(0)


# ---------------------------------------------------------------------------
# mesh carving + submesh leasing
# ---------------------------------------------------------------------------
def test_make_wave_mesh_single_device():
    from repro.launch.mesh import make_wave_mesh

    mesh = make_wave_mesh()  # 1 CPU device -> (1, 1)
    assert mesh.axis_names == ("lane", "data")
    assert dict(mesh.shape) == {"lane": 1, "data": 1}


def test_make_wave_mesh_validates_device_budget():
    from repro.launch.mesh import make_wave_mesh

    with pytest.raises(ValueError):
        make_wave_mesh(lanes=8)  # needs 8 devices, host has 1
    with pytest.raises(ValueError):
        make_wave_mesh(data=3)  # 1 device does not split into 3 shards
    with pytest.raises(ValueError):
        make_wave_mesh(lanes=0)


def test_submesh_pool_keys_on_worker_not_k():
    """Regression: the distributed-fit executor used ``submeshes[k % n]``,
    so two concurrent workers whose ks collided mod n serialized on one
    device group. The pool leases per worker thread instead."""
    from repro.launch.mesh import SubmeshPool

    subs = [object(), object()]  # pool never touches the mesh itself
    pool = SubmeshPool(subs)
    leases = {}
    barrier = threading.Barrier(2)

    def worker(name, ks):
        barrier.wait()
        got = {pool.acquire() for _ in ks}  # every k, same worker
        assert len(got) == 1  # stable lease across this worker's ks
        leases[name] = got.pop()

    # both workers draw only even ks — k % 2 would land both on subs[0]
    t1 = threading.Thread(target=worker, args=("a", [2, 4, 8]))
    t2 = threading.Thread(target=worker, args=("b", [6, 10, 12]))
    t1.start(); t2.start(); t1.join(); t2.join()
    assert leases["a"] is not leases["b"]
    assert set(pool.assignments().values()) == {0, 1}
    with pytest.raises(ValueError):
        SubmeshPool([])


# ---------------------------------------------------------------------------
# persistent compile cache
# ---------------------------------------------------------------------------
def test_enable_persistent_cache_configures_jax(tmp_path):
    import jax

    from repro.core import cache_entry_count, enable_persistent_cache

    prev = jax.config.jax_compilation_cache_dir
    try:
        assert enable_persistent_cache(str(tmp_path / "cache")) is True
        assert jax.config.jax_compilation_cache_dir == str(tmp_path / "cache")
        assert os.path.isdir(tmp_path / "cache")
        assert cache_entry_count(str(tmp_path / "cache")) == 0
        assert cache_entry_count(str(tmp_path / "missing")) == 0
    finally:
        jax.config.update("jax_compilation_cache_dir", prev)


# ---------------------------------------------------------------------------
# telemetry plumbing
# ---------------------------------------------------------------------------
def test_wavefront_publishes_lane_utilization_gauge():
    from repro.core import WavefrontScheduler, make_space
    from repro.obs import Metrics, use_metrics

    class Plane:
        last_lane_utilization = None

        def evaluate_batch(self, ks):
            self.last_lane_utilization = len(ks) / 8
            return [1.0 if k <= 5 else 0.0 for k in ks]

    metrics = Metrics()
    with use_metrics(metrics):
        WavefrontScheduler(make_space((2, 9), 0.7)).run(Plane())
    util = metrics.gauge("lane_utilization")
    assert util is not None and 0.0 < util <= 1.0


def test_null_tracer_accepts_injected_spans():
    from repro.obs import NULL_TRACER

    NULL_TRACER.add_span("lane", 0.0, 5.0, track="device:3", ks=[2, 4])
    NULL_TRACER.add_event("compile", 0.0, track="device:all")
    assert NULL_TRACER.now_us() == 0.0
    assert NULL_TRACER.events() == []


def test_tracer_now_us_pairs_with_add_span():
    from repro.obs import Tracer

    clock = iter([0.0, 1.0, 2.0])
    t = Tracer(clock=lambda: next(clock))
    t0 = t.now_us()  # 1.0 - 0.0 seconds -> 1e6 us
    t.add_span("lane", t0, t.now_us() - t0, track="device:0", n_real=3)
    (rec,) = t.events()
    assert rec["ts"] == pytest.approx(1e6)
    assert rec["dur"] == pytest.approx(1e6)
    assert rec["track"] == "device:0"


# ---------------------------------------------------------------------------
# device-heavy parity (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------
def test_sharded_parity_under_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p
    )
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_sharded_child.py")],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900,
    )
    assert proc.returncode == 0, f"child failed:\n{proc.stdout}\n{proc.stderr}"
    assert "sharded child OK" in proc.stdout
