"""Elastic wavefront executor: convergence-gated chunked fits, lane refill,
cross-k warm starts, and the §III-D chunk-boundary abort path.

The fixed-iteration oracle for every comparison here is the batched plane
(``NMFkBatchPlane``): at ``tol=0`` / ``warm_start=False`` the elastic plane
runs the identical draw schedule in chunks, so curves must agree exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ElasticWavefrontScheduler,
    LaneRefillPolicy,
    as_eval_plane,
    binary_bleed_search,
    make_space,
)
from repro.factorization.batching import WarmStartCache
from repro.factorization.planes import (
    KMeansBatchPlane,
    NMFkBatchPlane,
    NMFkElasticPlane,
)
from repro.factorization.synthetic import blob_data, nmf_data

KEY = jax.random.PRNGKey(0)


@functools.lru_cache(maxsize=1)
def _fixture():
    v, _, _ = nmf_data(jax.random.fold_in(KEY, 2), n=48, m=52, k_true=4)
    return v


def _drain(plane):
    """Submit nothing new; tick until idle, collecting {k: score}."""
    scores = {}
    while not plane.idle:
        for k, s in plane.tick():
            scores[k] = s
    return scores


FIT = dict(n_perturbs=3, nmf_iters=45, k_pad=6, chunk=15, warm_start=False)
KS = [3, 4, 5]


@functools.lru_cache(maxsize=16)
def _elastic_curve(tol: float):
    """(scores over KS, total sweeps run) at the given convergence tol."""
    plane = NMFkElasticPlane(_fixture(), KEY, tol=tol, **FIT)
    for k in KS:
        plane.submit(k)
    scores = _drain(plane)
    return tuple(scores[k] for k in KS), plane.sweeps_run


# ---------------------------------------------------------------------------
# warm-start cache
# ---------------------------------------------------------------------------
def test_warm_cache_prefers_near_same_perturbation_then_smaller_k():
    c = WarmStartCache(window=8)
    w = {k: jnp.full((4, 8), float(k)) for k in (4, 5, 7, 8)}
    c.put(5, 0, w[5])
    c.put(7, 1, w[7])
    # distance tie (5 and 7 both at |k-6|=1): same perturbation wins
    k_src, w_src = c.nearest(6, 0)
    assert k_src == 5 and float(w_src[0, 0]) == 5.0
    # same distance + same perturbation on both sides: smaller k wins
    c2 = WarmStartCache(window=8)
    c2.put(4, 0, w[4])
    c2.put(8, 0, w[8])
    assert c2.nearest(6, 0)[0] == 4
    # closest k beats everything else
    assert c2.nearest(8, 1)[0] == 8


def test_warm_cache_window_and_fifo_eviction():
    c = WarmStartCache(window=2, max_ks=3)
    for k in (2, 3, 4):
        c.put(k, 0, jnp.zeros((2, 4)))
    assert c.nearest(9, 0) is None  # all further than window
    assert c.misses == 1
    c.put(5, 0, jnp.zeros((2, 4)))  # evicts k=2 (FIFO beyond max_ks)
    assert c.nearest(2, 0)[0] == 3
    assert c.hits == 1


# ---------------------------------------------------------------------------
# elastic plane vs the fixed-iteration batched oracle
# ---------------------------------------------------------------------------
def test_elastic_tol_zero_matches_batched_exactly():
    curve, sweeps = _elastic_curve(0.0)
    batched = NMFkBatchPlane(
        _fixture(), KEY, n_perturbs=FIT["n_perturbs"],
        nmf_iters=FIT["nmf_iters"], k_pad=FIT["k_pad"],
    )
    np.testing.assert_allclose(
        np.asarray(curve), np.asarray(batched.evaluate_batch(KS)), atol=1e-6,
        err_msg="tol=0 elastic fits must be draw-for-draw the batched fits",
    )
    assert sweeps == len(KS) * FIT["n_perturbs"] * FIT["nmf_iters"]


TOL_LADDER = [3e-2, 3e-3, 1e-3, 1e-4, 1e-6, 0.0]


@settings(max_examples=15, deadline=None)
@given(i=st.integers(min_value=0, max_value=len(TOL_LADDER) - 2))
def test_tightening_tol_converges_to_fixed_iteration_oracle(i):
    """Property: along a descending tol ladder, scores approach the tol=0
    oracle monotonically while sweeps run monotonically grow — the gate can
    only fire earlier at a looser tol."""
    oracle = np.asarray(_elastic_curve(0.0)[0])
    loose, tight = TOL_LADDER[i], TOL_LADDER[i + 1]
    c_loose, sw_loose = _elastic_curve(loose)
    c_tight, sw_tight = _elastic_curve(tight)
    dev_loose = float(np.max(np.abs(np.asarray(c_loose) - oracle)))
    dev_tight = float(np.max(np.abs(np.asarray(c_tight) - oracle)))
    assert sw_tight >= sw_loose
    assert dev_tight <= dev_loose + 1e-7


def test_elastic_search_matches_batched_search_and_accounting():
    v = _fixture()
    mk = dict(n_perturbs=3, nmf_iters=45, k_pad=6)
    plane = NMFkElasticPlane(v, KEY, tol=0.0, chunk=15, warm_start=False, **mk)
    res = ElasticWavefrontScheduler(make_space((2, 6), 0.8)).run(plane)
    batched = NMFkBatchPlane(v, KEY, **mk)
    ref = {k: s for k, s in zip(res.visited_ks, batched.evaluate_batch(res.visited_ks))}
    got = {rec.k: rec.score for rec in res.visits}
    assert res.k_optimal == 4
    for k in got:
        assert abs(got[k] - ref[k]) < 1e-6, f"k={k}: {got[k]} vs {ref[k]}"
    # the bench invariant holds over the whole search, evictions included
    assert plane.sweeps_run + plane.sweeps_saved == plane.sweeps_fixed_total
    assert len(res.visits) + (res.n_candidates - res.n_visited) == res.n_candidates


def test_elastic_api_executor_and_warm_start_agree_on_k_opt():
    v = _fixture()
    plane = NMFkElasticPlane(
        v, KEY, n_perturbs=3, nmf_iters=45, k_pad=6, tol=1e-4, chunk=15,
        warm_start=True,
    )
    res = binary_bleed_search(plane, (2, 6), 0.8, executor="elastic")
    assert res.k_optimal == 4
    assert plane.warm_cache.hits > 0  # refilled lanes actually warm-started
    assert plane.sweeps_run + plane.sweeps_saved == plane.sweeps_fixed_total


def test_elastic_cancel_evicts_inflight_and_credits_saved():
    v = _fixture()
    plane = NMFkElasticPlane(
        v, KEY, n_perturbs=3, nmf_iters=45, k_pad=6, tol=0.0, chunk=15,
        warm_start=False,
    )
    plane.submit(4)
    plane.submit(5)
    plane.tick()  # one chunk in flight for both ks
    assert plane.inflight_ks() == {4, 5}
    assert plane.cancel(5)
    assert plane.inflight_ks() == {4}
    assert plane.sweeps_saved > 0  # 5's unspent sweeps were credited
    assert not plane.cancel(5)  # idempotent: already gone
    scores = _drain(plane)
    assert set(scores) == {4}
    assert plane.sweeps_run + plane.sweeps_saved == plane.sweeps_fixed_total


def test_refill_policy_admits_up_to_backlog_cap():
    class FakePlane:
        slots = 4
        backlog = 0

    pol = LaneRefillPolicy(order="pre", max_backlog=2)
    p = FakePlane()
    assert pol.admit(p)
    p.backlog = 2
    assert not pol.admit(p)
    # default cap falls back to the plane's slot count
    assert LaneRefillPolicy().admit(p)
    # the candidate stream is exactly the pre-order traversal worklist
    assert sorted(pol.worklist([2, 3, 4, 5])) == [2, 3, 4, 5]
    assert pol.worklist([2, 3, 4, 5])[0] not in (2, 5)  # midpoint-first


# ---------------------------------------------------------------------------
# §III-D abort: chunk-boundary polling through the batch planes
# ---------------------------------------------------------------------------
def test_nmfk_chunked_scalar_matches_fused_when_never_aborted():
    v = _fixture()
    plane = NMFkBatchPlane(v, KEY, n_perturbs=3, nmf_iters=45, k_pad=6)
    got = plane.evaluate_one(4, should_abort=lambda: False)
    want = plane.evaluate_batch([4])[0]
    assert abs(got - want) < 1e-6
    assert plane.last_scalar_sweeps == 3 * 45


def test_nmfk_pruned_k_stops_consuming_sweeps():
    """Regression: the batched planes used to drop ``should_abort`` on the
    floor, so a §III-D prune still paid the full fit. Now the scalar path
    is chunked and the abort lands at the next chunk boundary."""
    v = _fixture()
    plane = NMFkBatchPlane(v, KEY, n_perturbs=3, nmf_iters=75, k_pad=6)
    polls = []

    def abort_after_first_chunk():
        polls.append(True)
        return len(polls) > 1

    score = plane.evaluate_one(4, should_abort=abort_after_first_chunk)
    # one chunk (abort_chunk sweeps x P lanes) ran, the remaining two never did
    assert plane.last_scalar_sweeps == plane.abort_chunk * 3
    assert plane.last_scalar_sweeps < 75 * 3
    # partial ensemble still scores (accounting only — the k was pruned)
    assert np.isfinite(score)


def test_nmfk_abort_before_first_chunk_is_void_score():
    v = _fixture()
    plane = NMFkBatchPlane(v, KEY, n_perturbs=2, nmf_iters=45, k_pad=6)
    score = plane.evaluate_one(4, should_abort=lambda: True)
    assert np.isnan(score)
    assert plane.last_scalar_sweeps == 0
    # NaN is void: neither threshold test selects it, bounds are untouched
    space = make_space((2, 6), 0.8, stop_threshold=0.1)
    assert not space.selects(score) and not space.stops(score)


def test_kmeans_chunked_scalar_abort():
    x, _ = blob_data(jax.random.fold_in(KEY, 3), n=120, d=4, k_true=4)
    plane = KMeansBatchPlane(x, KEY, score="silhouette", max_iters=25, k_pad=8)
    got = plane.evaluate_one(4, should_abort=lambda: False)
    want = plane.evaluate_batch([4])[0]
    assert abs(got - want) < 1e-5
    assert np.isnan(plane.evaluate_one(4, should_abort=lambda: True))


def test_batch_only_adapter_polls_abort_before_dispatch():
    calls = []

    class BatchOnly:
        def evaluate_batch(self, ks):
            calls.append(list(ks))
            return [1.0 for _ in ks]

    plane = as_eval_plane(BatchOnly())
    assert np.isnan(plane.evaluate_one(5, should_abort=lambda: True))
    assert calls == []  # pruned-while-queued k never paid for its fit
    assert plane.evaluate_one(5, should_abort=lambda: False) == 1.0
    assert calls == [[5]]
