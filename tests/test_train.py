"""Training substrate: loss goes down, optimizer math, grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, reduced_config
from repro.models.transformer import Model
from repro.train.compression import compress_tree, dequantize_int8, quantize_int8
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_at
from repro.train.train_step import TrainConfig, auto_train_config, make_train_step

KEY = jax.random.PRNGKey(0)


def test_loss_decreases_qwen():
    from repro.launch.train import main

    out = main(["--arch", "qwen2-0.5b", "--steps", "15", "--batch", "8", "--seq", "32",
                "--lr", "3e-3", "--quiet"])
    assert out["losses"][-1] < out["losses"][0] * 0.9


def test_loss_decreases_moe():
    from repro.launch.train import main

    out = main(["--arch", "granite-moe-1b-a400m", "--steps", "12", "--batch", "8",
                "--seq", "32", "--lr", "3e-3", "--quiet"])
    assert out["losses"][-1] < out["losses"][0]


def test_loss_decreases_rwkv():
    from repro.launch.train import main

    out = main(["--arch", "rwkv6-1.6b", "--steps", "12", "--batch", "8", "--seq", "32",
                "--lr", "3e-3", "--quiet"])
    assert out["losses"][-1] < out["losses"][0]


def test_microbatching_matches_single_batch():
    """Grad accumulation over n microbatches == one big batch (linear loss)."""
    cfg = reduced_config(registry()["qwen2-0.5b"])
    model = Model(cfg, remat="none", dtype=jnp.float32)
    params = model.init(KEY)
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    tokens = jax.random.randint(KEY, (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    outs = []
    for n in (1, 4):
        step = make_train_step(model, TrainConfig(opt=opt_cfg, microbatches=n))
        opt = init_opt_state(params, opt_cfg)
        p2, _, metrics = step(params, opt, batch)
        outs.append((float(metrics["loss"]), p2))
    assert abs(outs[0][0] - outs[1][0]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(outs[1][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_adamw_matches_reference():
    """Single-tensor AdamW against a hand-rolled numpy reference."""
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                      grad_clip=1e9, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]], jnp.float32)}
    st = init_opt_state(p, cfg)
    p2, st2, _ = adamw_update(p, g, st, cfg)
    # numpy reference
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mh, vh = m / (1 - 0.9), v / (1 - 0.99)
    lr = float(lr_at(cfg, jnp.asarray(1)))
    want = np.asarray(p["w"]) - lr * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)
    assert int(st2.step) == 1


def test_grad_clip_caps_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0, warmup_steps=0)
    p = {"w": jnp.zeros((4,), jnp.float32)}
    g = {"w": 1e6 * jnp.ones((4,), jnp.float32)}
    st = init_opt_state(p, cfg)
    _, _, metrics = adamw_update(p, g, st, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(lr_at(cfg, jnp.asarray(100))) - 0.1) < 1e-3


def test_int8_quantization_roundtrip_error():
    x = jax.random.normal(KEY, (1000,)) * 0.01
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape, x.dtype)
    rel = float(jnp.linalg.norm(back - x) / jnp.linalg.norm(x))
    assert rel < 0.01  # blockwise int8 keeps ~1% error


@pytest.mark.parametrize("mode", ["none", "bf16", "int8"])
def test_compression_modes(mode):
    g = {"a": jax.random.normal(KEY, (64, 64)) * 0.01}
    out = compress_tree(g, mode)
    rel = float(jnp.linalg.norm(out["a"].astype(jnp.float32) - g["a"]) / jnp.linalg.norm(g["a"]))
    assert rel < (0.02 if mode != "none" else 1e-9)


def test_auto_train_config_fits_batch():
    # >=100B: 4 microbatches (hillclimbed: halving accumulation steps halves
    # FSDP weight-gather traffic; see EXPERIMENTS.md §Perf llama3-405b)
    t = auto_train_config(405e9, 256, 16)
    assert t.microbatches == 4 and t.opt.state_dtype == jnp.bfloat16
    t = auto_train_config(405e9, 256, 32)
    assert (256 // t.microbatches) % 32 == 0
    t = auto_train_config(1e9, 256, 16)
    assert (256 // t.microbatches) % 16 == 0
