"""Cross-executor conformance child — run under N forced CPU devices.

Invoked by ``tests/test_conformance.py`` as a subprocess with
``--xla_force_host_platform_device_count=<N>`` in XLA_FLAGS (the flag must
precede jax init, and the parent pytest process already holds a 1-device
runtime — same scaffolding as ``tests/_sharded_child.py``). argv[1] is the
expected device count.

The conformance matrix: executors {scalar, batched, lane-sharded,
data-sharded sync, data-sharded pipelined, elastic} × models {NMFk, KMeans
(elastic is NMFk-only)}, all on
fixed seeds, asserting identical ``k_optimal`` from every executor's
search (pinned to the planted rank, not just mutual agreement) and score
agreement within the documented tolerances:

  TOL_LANE = 1e-5  lane-sharded vs batched, and scalar vs batched for
                   K-Means: identical fp schedule — shard_map only splits
                   the vmap batch axis, and masked K-Means lanes are
                   draw-for-draw the per-k fits. Applies to whole curves.
  TOL_DATA = 2e-3  data-sharded sync vs batched: Gram psums reduce in a
                   different float order than the one-device matmul.
                   Applies to whole curves.
  TOL_PIPE = 5e-2  pipelined vs batched **at the selected rank**: the
                   one-sweep-stale schedule plus the final synchronous
                   sweep converges to the same well-determined optimum.
                   Away from the selected rank NMFk's min-silhouette
                   measures ensemble *stability*, which is chaotic under
                   any fp-schedule perturbation (a stale sweep can tip one
                   perturbation into a different basin, e.g. ~0.29 vs
                   ~0.86 at k=2 on this fixture), so off-optimum ranks are
                   held to k_optimal/threshold-decision conformance, not a
                   pointwise bound.

Scalar vs batched NMFk: the masked ensemble coincides with the unpadded
scalar fit only at k == k_pad, so that single rank is asserted at TOL_LANE
(plus k_optimal identity from the scalar worklist search).
"""
from __future__ import annotations

import sys

import numpy as np

TOL_LANE = 1e-5
TOL_DATA = 2e-3
TOL_PIPE = 5e-2


def _searches_agree(space_args, planes, scalar_evaluate, k_expected, core):
    """k_optimal from the scalar worklist and every plane's wavefront run."""
    WavefrontScheduler, binary_bleed_worklist, make_space = core
    k_opts = {"scalar": binary_bleed_worklist(
        make_space(*space_args), scalar_evaluate).k_optimal}
    for name, make_plane in planes.items():
        k_opts[name] = WavefrontScheduler(make_space(*space_args)).run(
            make_plane()).k_optimal
    assert all(k == k_expected for k in k_opts.values()), (
        f"k_optimal diverged from planted rank {k_expected}: {k_opts}"
    )
    return k_opts


def main() -> None:
    n_devices = int(sys.argv[1])

    import jax

    assert jax.device_count() == n_devices, (
        f"expected {n_devices} forced devices, got {jax.device_count()}"
    )

    from repro.core import WavefrontScheduler, binary_bleed_worklist, make_space
    from repro.core.scoring import silhouette_score
    from repro.factorization.kmeans import kmeans
    from repro.factorization.nmfk import make_nmfk_evaluator, nmfk_score
    from repro.factorization.planes import KMeansBatchPlane, NMFkBatchPlane
    from repro.factorization.synthetic import blob_data, nmf_data

    core = (WavefrontScheduler, binary_bleed_worklist, make_space)
    data = 2 if n_devices >= 2 else 1
    mesh_lane = jax.make_mesh((n_devices, 1), ("lane", "data"), devices=jax.devices())
    mesh_data = jax.make_mesh(
        (n_devices // data, data), ("lane", "data"), devices=jax.devices()
    )

    key = jax.random.PRNGKey(0)

    # ---------------- NMFk ------------------------------------------------
    v, _, _ = nmf_data(key, n=72, m=80, k_true=4)
    fit = dict(n_perturbs=3, nmf_iters=60, k_pad=8)
    ks = list(range(2, 9))

    def nmfk_planes():
        return {
            "batched": lambda: NMFkBatchPlane(v, key, **fit),
            "lane": lambda: NMFkBatchPlane(v, key, mesh=mesh_lane, **fit),
            "data_sync": lambda: NMFkBatchPlane(v, key, mesh=mesh_data, **fit),
            "pipelined": lambda: NMFkBatchPlane(
                v, key, mesh=mesh_data, comm="pipelined", **fit
            ),
        }

    curves = {name: mk().evaluate_batch(ks) for name, mk in nmfk_planes().items()}

    np.testing.assert_allclose(
        curves["lane"], curves["batched"], atol=TOL_LANE,
        err_msg="lane-sharded NMFk curve diverged from batched",
    )
    np.testing.assert_allclose(
        curves["data_sync"], curves["batched"],
        atol=TOL_DATA if data > 1 else TOL_LANE,
        err_msg="data-sharded sync NMFk curve outside psum reduction-order tol",
    )
    k_star = ks[int(np.argmax(curves["batched"]))]
    pipe_tol = TOL_PIPE if data > 1 else TOL_LANE
    assert abs(curves["pipelined"][ks.index(k_star)]
               - curves["batched"][ks.index(k_star)]) < pipe_tol, (
        f"pipelined NMFk score at selected rank {k_star} outside tolerance: "
        f"{curves['pipelined'][ks.index(k_star)]} vs {curves['batched'][ks.index(k_star)]}"
    )

    # scalar agreement at the exact-schedule rank k == k_pad
    sc = nmfk_score(
        v, fit["k_pad"], jax.random.fold_in(key, fit["k_pad"]),
        n_perturbs=fit["n_perturbs"], nmf_iters=fit["nmf_iters"],
    )
    np.testing.assert_allclose(
        curves["batched"][ks.index(fit["k_pad"])],
        float(sc.min_silhouette), atol=TOL_LANE,
        err_msg="batched NMFk lane at k == k_pad diverged from the scalar fit",
    )

    scalar_eval = make_nmfk_evaluator(
        v, key, n_perturbs=fit["n_perturbs"], nmf_iters=fit["nmf_iters"]
    )
    k_opts = _searches_agree(((2, 8), 0.8), nmfk_planes(), scalar_eval, 4, core)

    # ---------------- elastic executor ------------------------------------
    # At tol=0 / warm_start=False the elastic plane's chunked lanes are
    # draw-for-draw the batched plane's fixed-iteration fits, so its curves
    # inherit the batched tolerances (TOL_LANE lane-sharded, TOL_DATA
    # data-sharded). The searches then run the production config (gated tol
    # + warm starts) and must still land on the planted rank.
    from repro.core import ElasticWavefrontScheduler
    from repro.factorization.planes import NMFkElasticPlane

    def elastic_planes(**over):
        cfg = dict(fit, chunk=20, warm_start=False, tol=0.0)
        cfg.update(over)
        return {
            "elastic": lambda: NMFkElasticPlane(v, key, **cfg),
            "elastic_lane": lambda: NMFkElasticPlane(v, key, mesh=mesh_lane, **cfg),
            "elastic_data": lambda: NMFkElasticPlane(v, key, mesh=mesh_data, **cfg),
        }

    for name, mk in elastic_planes().items():
        plane = mk()
        for k in ks:
            plane.submit(k)
        scores = {}
        while not plane.idle:
            for kk, s in plane.tick():
                scores[kk] = s
        tol = TOL_DATA if (name == "elastic_data" and data > 1) else TOL_LANE
        np.testing.assert_allclose(
            [scores[k] for k in ks], curves["batched"], atol=tol,
            err_msg=f"{name} tol=0 curve diverged from the batched oracle",
        )

    for name, mk in elastic_planes(tol=1e-4, warm_start=True).items():
        plane = mk()
        res = ElasticWavefrontScheduler(make_space((2, 8), 0.8)).run(plane)
        assert res.k_optimal == 4, (
            f"{name} gated/warm search diverged from planted rank: {res.k_optimal}"
        )
        assert plane.sweeps_run + plane.sweeps_saved == plane.sweeps_fixed_total, (
            f"{name} sweep accounting broke: {plane.sweeps_run} + "
            f"{plane.sweeps_saved} != {plane.sweeps_fixed_total}"
        )

    # ---------------- KMeans ----------------------------------------------
    xk, _ = blob_data(key, n=240, d=5, k_true=5, std=0.3, spread=10.0)
    km = dict(score="silhouette", max_iters=25, k_pad=10)
    km_ks = list(range(2, 11))

    def km_planes():
        return {
            "batched": lambda: KMeansBatchPlane(xk, key, **km),
            "lane": lambda: KMeansBatchPlane(xk, key, mesh=mesh_lane, **km),
            # comm is a documented no-op for lane-only K-Means dispatches
            "pipelined": lambda: KMeansBatchPlane(
                xk, key, mesh=mesh_lane, comm="pipelined", **km
            ),
        }

    km_curves = {name: mk().evaluate_batch(km_ks) for name, mk in km_planes().items()}

    def km_scalar(k, should_abort=None):
        res = kmeans(xk, int(k), jax.random.fold_in(key, int(k)),
                     max_iters=km["max_iters"])
        return float(silhouette_score(xk, res.labels, int(k)))

    scalar_curve = [km_scalar(k) for k in km_ks]
    for name, curve in km_curves.items():
        np.testing.assert_allclose(
            curve, scalar_curve, atol=TOL_LANE,
            err_msg=f"{name} K-Means curve diverged from the scalar fits",
        )

    km_opts = _searches_agree(((2, 10), 0.9), km_planes(), km_scalar, 5, core)

    print(f"conformance child OK devices={n_devices} "
          f"nmfk_k={k_opts['scalar']} kmeans_k={km_opts['scalar']}")


if __name__ == "__main__":
    main()
