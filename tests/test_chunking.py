"""Algorithm 2 chunking + Table II T1-T4 composition + elastic rebalance."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.chunking import chunk_block, chunk_skip_mod, plan_worklists, rebalance

KS = list(range(1, 12))


def test_skip_mod_matches_paper():
    assert chunk_skip_mod(KS, 2) == [[1, 3, 5, 7, 9, 11], [2, 4, 6, 8, 10]]


def test_t2_matches_paper():
    # Table II T2 pre-order: sort whole K, then Alg-2 chunk
    assert plan_worklists(KS, 2, "pre", "T2") == [[3, 1, 5, 9, 7, 11], [6, 2, 4, 8, 10]]


def test_t4_matches_paper():
    # Table II T4 pre-order: Alg-2 chunk, then per-chunk sort
    assert plan_worklists(KS, 2, "pre", "T4") == [[7, 3, 1, 5, 11, 9], [6, 4, 2, 10, 8]]


def test_t4_postorder_matches_paper_modulo_typo():
    # paper prints [2,4,9,10,6] — 9 is already in chunk 1; correct is [2,4,8,10,6]
    assert plan_worklists(KS, 2, "post", "T4") == [[1, 5, 3, 9, 11, 7], [2, 4, 8, 10, 6]]


def test_t1_t3_block_structure():
    t1 = plan_worklists(KS, 2, "pre", "T1")
    assert [len(c) for c in t1] == [6, 5]
    t3 = plan_worklists(KS, 2, "pre", "T3")
    # block chunk then per-chunk sort: first chunk only holds low k
    assert set(t3[0]) == set(range(1, 7))


@given(
    ks=st.lists(st.integers(0, 5000), min_size=1, max_size=300, unique=True),
    r=st.integers(1, 12),
    strategy=st.sampled_from(["T1", "T2", "T3", "T4"]),
)
@settings(max_examples=80, deadline=None)
def test_chunking_partitions_exactly(ks, r, strategy):
    chunks = plan_worklists(ks, r, "pre", strategy)
    assert len(chunks) == r
    flat = [k for c in chunks for k in c]
    assert sorted(flat) == sorted(ks)


@given(ks=st.lists(st.integers(0, 5000), min_size=1, max_size=300, unique=True), r=st.integers(1, 12))
@settings(max_examples=50, deadline=None)
def test_skip_mod_balanced(ks, r):
    chunks = chunk_skip_mod(ks, r)
    sizes = [len(c) for c in chunks]
    assert max(sizes) - min(sizes) <= 1  # load balance (paper's motivation)


def test_skip_mod_spreads_low_and_high():
    # each resource must hold both low and high k (T1's failure mode)
    chunks = chunk_skip_mod(list(range(1, 101)), 4)
    for c in chunks:
        assert min(c) <= 10 and max(c) >= 90


def test_rebalance_deterministic():
    a = rebalance([5, 3, 9, 7, 1], 2)
    b = rebalance([1, 3, 5, 7, 9], 2)
    assert a == b


def test_block_chunk_sizes():
    assert [len(c) for c in chunk_block(KS, 3)] == [4, 4, 3]


def test_invalid_resources():
    with pytest.raises(ValueError):
        chunk_skip_mod(KS, 0)
