"""Child process for tests/test_sharded_plane.py — needs 8 XLA devices.

``--xla_force_host_platform_device_count`` must be set before jax
initializes, and the parent pytest process has already initialized a
1-device runtime, so the device-heavy sharded-plane assertions run here:
the parent re-execs this script with the flag in XLA_FLAGS and checks the
exit status. Every assertion failure prints before a non-zero exit.
"""
from __future__ import annotations

import numpy as np


def main() -> None:
    import jax

    assert jax.device_count() == 8, f"expected 8 forced devices, got {jax.device_count()}"

    import jax.numpy as jnp

    from repro.core import WavefrontScheduler, make_space
    from repro.factorization.nmfk import nmfk_score
    from repro.factorization.planes import KMeansBatchPlane, NMFkBatchPlane

    key = jax.random.PRNGKey(0)
    kv = jax.random.fold_in(key, 99)
    w = jax.random.uniform(jax.random.fold_in(kv, 1), (48, 4))
    h = jax.random.uniform(jax.random.fold_in(kv, 2), (4, 36))
    v = w @ h

    mesh = jax.make_mesh((8, 1), ("lane", "data"), devices=jax.devices())
    mesh42 = jax.make_mesh((4, 2), ("lane", "data"), devices=jax.devices())
    fit = dict(n_perturbs=3, nmf_iters=40, k_pad=10)

    batched = NMFkBatchPlane(v, key, **fit)
    sharded = NMFkBatchPlane(v, key, mesh=mesh, **fit)
    datash = NMFkBatchPlane(v, key, mesh=mesh42, **fit)

    # full wave (multiple of lane count): lane-sharded is score-for-score
    # the batched plane; data-sharded differs only by psum reduction order
    ks = list(range(2, 10))
    ref = batched.evaluate_batch(ks)
    np.testing.assert_allclose(sharded.evaluate_batch(ks), ref, atol=1e-5)
    np.testing.assert_allclose(datash.evaluate_batch(ks), ref, atol=2e-3)

    # non-multiple-of-lane wave and singleton: padding keeps parity and
    # reuses the (8, k_pad) bucket instead of minting new shapes
    np.testing.assert_allclose(
        sharded.evaluate_batch([2, 3, 4, 5, 6]),
        batched.evaluate_batch([2, 3, 4, 5, 6]),
        atol=1e-5,
    )
    np.testing.assert_allclose(
        sharded.evaluate_one(7), batched.evaluate_one(7), atol=1e-5
    )
    assert sharded.shapes_compiled == {(8, 10)}, sharded.shapes_compiled

    # scalar oracle: at k == k_pad the padded fit is the unpadded fit
    oracle = NMFkBatchPlane(v, key, n_perturbs=3, nmf_iters=40, k_pad=8, mesh=mesh)
    sc = nmfk_score(
        v, 8, jax.random.fold_in(key, 8), n_perturbs=3, nmf_iters=40
    )
    np.testing.assert_allclose(
        oracle.evaluate_batch([8])[0], float(sc.min_silhouette), atol=1e-5
    )

    # kmeans: lane-sharded matches batched; data axis > 1 is rejected
    xk = jax.random.normal(jax.random.fold_in(key, 5), (64, 3)) + 3.0 * jax.random.randint(
        jax.random.fold_in(key, 6), (64, 1), 0, 4
    ).astype(jnp.float32)
    km_b = KMeansBatchPlane(xk, key, k_pad=8, max_iters=25)
    km_s = KMeansBatchPlane(xk, key, k_pad=8, max_iters=25, mesh=mesh)
    np.testing.assert_allclose(
        km_s.evaluate_batch([2, 3, 4, 5, 6, 7]),
        km_b.evaluate_batch([2, 3, 4, 5, 6, 7]),
        atol=1e-5,
    )
    try:
        KMeansBatchPlane(xk, key, k_pad=8, mesh=mesh42)
    except ValueError:
        pass
    else:
        raise AssertionError("KMeansBatchPlane accepted a data-sharded mesh")

    # end-to-end: the wavefront search lands on the same k through either
    # executor, and bucketing holds the sharded search to <= 4 jit shapes
    space = make_space((2, 16), 0.7)
    p_b = NMFkBatchPlane(v, key, n_perturbs=2, nmf_iters=30, k_pad=16)
    p_s = NMFkBatchPlane(v, key, n_perturbs=2, nmf_iters=30, k_pad=16, mesh=mesh)
    r_b = WavefrontScheduler(space).run(p_b)
    r_s = WavefrontScheduler(space).run(p_s)
    assert r_s.k_optimal == r_b.k_optimal, (r_s.k_optimal, r_b.k_optimal)
    assert len(p_s.shapes_compiled) <= 4, p_s.shapes_compiled

    print("sharded child OK")


if __name__ == "__main__":
    main()
