"""Coordinator (Redis-replacement) — monotone-merge properties + journal."""
import math
import os
import threading
import time

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Bounds, FileCoordinator, InProcessCoordinator, make_space
from repro.core.coordinator import merge_all
from repro.obs import Metrics, Tracer, use_metrics, use_tracer

bounds_st = st.builds(
    Bounds,
    lo_bound=st.one_of(st.just(-math.inf), st.integers(-50, 50).map(float)),
    hi_bound=st.one_of(st.just(math.inf), st.integers(-50, 50).map(float)),
    k_optimal=st.one_of(st.none(), st.integers(0, 50)),
)


@given(a=bounds_st, b=bounds_st)
@settings(max_examples=100, deadline=None)
def test_merge_commutative(a, b):
    assert a.merge(b) == b.merge(a)


@given(a=bounds_st, b=bounds_st, c=bounds_st)
@settings(max_examples=100, deadline=None)
def test_merge_associative(a, b, c):
    assert a.merge(b).merge(c) == a.merge(b.merge(c))


@given(a=bounds_st)
@settings(max_examples=50, deadline=None)
def test_merge_idempotent(a):
    assert a.merge(a) == a
    assert a.merge(Bounds.empty()) == a


@given(perm=st.permutations(list(range(6))))
@settings(max_examples=40, deadline=None)
def test_merge_order_invariant(perm):
    """Stale/reordered publishes are harmless — the distributed guarantee."""
    items = [Bounds(float(i), float(50 - i), i) for i in range(6)]
    reordered = [items[i] for i in perm]
    assert merge_all(items) == merge_all(reordered)


def test_inprocess_concurrent_publish():
    coord = InProcessCoordinator()

    def pub(i):
        coord.publish(Bounds(float(i), math.inf, i))

    threads = [threading.Thread(target=pub, args=(i,)) for i in range(32)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    b = coord.snapshot()
    assert b.lo_bound == 31.0 and b.k_optimal == 31


def test_file_coordinator_roundtrip(tmp_path):
    c = FileCoordinator(str(tmp_path))
    c.publish(Bounds(3.0, math.inf, 3))
    c.publish(Bounds(7.0, 20.0, 7))
    b = c.snapshot()
    assert b == Bounds(7.0, 20.0, 7)
    c.record_visit(7, 0.95, resource=1)
    c.record_visit(12, 0.1, resource=0)
    assert len(c.visits()) == 2


def test_file_coordinator_replay(tmp_path):
    """Journal replay rebuilds bounds + visited set — search restart."""
    space = make_space((2, 30), 0.7, 0.2)
    c = FileCoordinator(str(tmp_path))
    c.record_visit(16, 0.95, 0)  # selects -> prunes <=16
    c.record_visit(24, 0.05, 1)  # stops  -> prunes >=24
    bounds, visited = c.replay(space.selects, space.stops)
    assert visited == {16, 24}
    assert bounds.lo_bound == 16 and bounds.hi_bound == 24 and bounds.k_optimal == 16


def test_stale_lock_broken_with_event(tmp_path):
    """A lockfile whose holder died is broken on the next acquire — and the
    break is a visible ``lock_broken`` trace event, not a silent unlink."""
    c = FileCoordinator(str(tmp_path))
    with open(c._lock_path, "w") as f:
        f.write("999999")  # dead holder
    old = time.time() - 120
    os.utime(c._lock_path, (old, old))
    tr, m = Tracer(), Metrics()
    with use_tracer(tr), use_metrics(m):
        c.publish(Bounds(3.0, math.inf, 3))  # must break the stale lock
    assert c.snapshot().k_optimal == 3
    assert not os.path.exists(c._lock_path)  # released after publish
    assert m.counter("lock_broken") == 1
    (ev,) = [e for e in tr.events() if e["name"] == "lock_broken"]
    assert ev["args"]["age_s"] > 100


def test_fresh_lock_never_broken(tmp_path):
    """A live (recent-mtime) lock must NOT be broken — acquire times out."""
    c = FileCoordinator(str(tmp_path))
    with open(c._lock_path, "w") as f:
        f.write("1")
    m = Metrics()
    with use_metrics(m):
        with pytest.raises(TimeoutError):
            c._acquire(timeout=0.15, stale=30.0)
    assert os.path.exists(c._lock_path)  # untouched
    assert m.counter("lock_broken") == 0


def test_stale_lock_not_unlinked_if_replaced(tmp_path, monkeypatch):
    """The two-waiter race: between this waiter's staleness check and its
    unlink, another waiter broke the lock and a NEW holder created a fresh
    one. The re-stat guard must refuse to unlink the fresh lock."""
    c = FileCoordinator(str(tmp_path))
    with open(c._lock_path, "w") as f:
        f.write("1")
    old = time.time() - 120
    os.utime(c._lock_path, (old, old))

    real_stat = os.stat
    calls = {"n": 0}

    def racing_stat(path, *a, **kw):
        if path == c._lock_path:
            calls["n"] += 1
            if calls["n"] == 2:
                # interleave the other waiter between the staleness check
                # (call 1) and the pre-unlink re-stat (call 2): it breaks
                # the stale lock and a new holder creates a fresh one. Our
                # re-stat then sees a different (ino, mtime) and must NOT
                # unlink.
                os.unlink(c._lock_path)
                with open(c._lock_path, "w") as f:
                    f.write("42")  # new live holder
        return real_stat(path, *a, **kw)

    monkeypatch.setattr(os, "stat", racing_stat)
    m = Metrics()
    with use_metrics(m):
        with pytest.raises(TimeoutError):
            c._acquire(timeout=0.2, stale=30.0)
    # the fresh holder's lock survived the race
    assert open(c._lock_path).read() == "42"
    assert m.counter("lock_broken") == 0


def test_file_coordinator_publish_metrics(tmp_path):
    c = FileCoordinator(str(tmp_path))
    m = Metrics()
    with use_metrics(m):
        c.publish(Bounds(1.0, math.inf, 1))
        c.publish(Bounds(2.0, math.inf, 2))
    assert m.counter("publish_count") == 2
    assert m.histogram("publish_latency_s")["count"] == 2
    assert m.histogram("lock_wait_s")["count"] == 2


def test_file_coordinator_multiprocess_safety(tmp_path):
    """Concurrent writers through the lockfile keep merges consistent."""
    c = FileCoordinator(str(tmp_path))
    errs = []

    def pub(i):
        try:
            c.publish(Bounds(float(i), math.inf, i))
        except BaseException as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=pub, args=(i,)) for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert c.snapshot().k_optimal == 15
