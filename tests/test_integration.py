"""End-to-end integration: Binary Bleed wrapped around real model fits."""
import jax
import pytest

from repro.core import binary_bleed_search, grid_search
from repro.core.scoring import davies_bouldin_score
from repro.factorization import blob_data, kmeans, make_nmfk_evaluator, nmf_data

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def nmf_problem():
    v, _, _ = nmf_data(KEY, n=72, m=80, k_true=4)
    return v


def test_binary_bleed_nmfk_finds_k_true(nmf_problem):
    ev = make_nmfk_evaluator(nmf_problem, KEY, n_perturbs=4, nmf_iters=100)
    res = binary_bleed_search(ev, (2, 10), select_threshold=0.9, num_resources=1)
    assert res.k_optimal == 4
    assert res.n_visited < 9  # pruned vs the 9-point grid


def test_binary_bleed_agrees_with_grid(nmf_problem):
    ev = make_nmfk_evaluator(nmf_problem, KEY, n_perturbs=4, nmf_iters=100)
    bb = binary_bleed_search(ev, (2, 8), select_threshold=0.9, num_resources=1)
    gs = grid_search(ev, (2, 8), select_threshold=0.9)
    assert bb.k_optimal == gs.k_optimal
    assert bb.n_visited <= gs.n_visited


def test_binary_bleed_kmeans_davies_bouldin():
    """Paper's K-Means + DB minimization task on clean blobs."""
    x, _ = blob_data(KEY, n=240, d=5, k_true=5, std=0.3, spread=10.0)

    def ev(k, should_abort=None):
        res = kmeans(x, int(k), jax.random.fold_in(KEY, k))
        return float(davies_bouldin_score(x, res.labels, int(k)))

    res = binary_bleed_search(
        ev, (2, 12), select_threshold=0.5, stop_threshold=1.6, mode="minimize",
        num_resources=2,
    )
    assert res.k_optimal == 5


def test_parallel_search_matches_serial(nmf_problem):
    ev = make_nmfk_evaluator(nmf_problem, KEY, n_perturbs=4, nmf_iters=100)
    serial = binary_bleed_search(ev, (2, 10), 0.9, num_resources=1)
    par = binary_bleed_search(ev, (2, 10), 0.9, num_resources=3)
    assert serial.k_optimal == par.k_optimal == 4


def test_ksearch_driver_end_to_end(tmp_path):
    from repro.launch.ksearch import main

    args = [
        "--n", "72", "--m", "80", "--k-true", "4", "--k-max", "16",
        "--resources", "2", "--threshold", "0.9", "--nmf-iters", "100",
        "--n-perturbs", "4", "--journal", str(tmp_path / "j"), "--quiet",
    ]
    out = main(args)
    assert out["k_optimal"] == 4
    # threaded resources race, so pruning savings vary run to run — the
    # paper's guarantee is "never more than linear" (§III-D)
    assert out["visit_fraction"] <= 1.0
    # restart on the same journal: nothing new to evaluate, same answer
    out2 = main(args)
    assert out2["k_optimal"] == 4


def test_ksearch_distributed_fit_mode():
    from repro.launch.ksearch import main

    out = main([
        "--n", "64", "--m", "72", "--k-true", "3", "--k-max", "8",
        "--resources", "2", "--threshold", "0.9", "--nmf-iters", "80",
        "--n-perturbs", "3", "--distributed-fit", "--quiet",
    ])
    assert out["k_optimal"] == 3
