"""Evaluation plane: wavefront executor + mask-padded batched fits."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ScalarEvalPlane,
    WavefrontScheduler,
    as_eval_plane,
    binary_bleed_search,
    binary_bleed_worklist,
    make_space,
)
from repro.core.scoring import (
    davies_bouldin_score,
    davies_bouldin_score_masked,
    silhouette_score,
    silhouette_score_masked,
)
from repro.core.traversal import traversal_sort
from repro.factorization.kmeans import kmeans, kmeans_batched
from repro.factorization.nmf import nmf, nmf_batched, nmf_init
from repro.factorization.synthetic import blob_data, nmf_data

KEY = jax.random.PRNGKey(0)


def square_wave(k0):
    return lambda k: 1.0 if k <= k0 else 0.0


def laplacian(k0, width=2.0):
    return lambda k: math.exp(-abs(k - k0) / width)


# ---------------------------------------------------------------------------
# (a) WavefrontScheduler vs the serial worklist driver
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k0", [2, 5, 16, 24, 30])
def test_wavefront_squarewave_matches_serial(k0):
    space = make_space((2, 30), 0.7)
    sched = WavefrontScheduler(space)
    res = sched.run(square_wave(k0))
    ser = binary_bleed_worklist(space, square_wave(k0), order="pre")
    assert res.k_optimal == ser.k_optimal == k0
    worklist = traversal_sort(sorted(space.ks), "pre")
    assert set(res.visited_ks) <= set(worklist)
    assert res.n_visited <= len(space.ks)
    assert sched.n_dispatches <= math.ceil(math.log2(len(space.ks))) + 1


@pytest.mark.parametrize("k0", [7, 16, 21])
def test_wavefront_laplacian_matches_serial(k0):
    space = make_space((2, 30), 0.9, stop_threshold=0.05)
    res = WavefrontScheduler(space).run(laplacian(k0, width=0.5))
    ser = binary_bleed_worklist(space, laplacian(k0, width=0.5), order="pre")
    assert res.k_optimal == ser.k_optimal
    assert res.n_visited <= len(space.ks)


def test_wavefront_each_k_at_most_once_and_early_stop():
    calls = []
    space = make_space((2, 40), 0.7, stop_threshold=0.2)

    def ev(k):
        calls.append(k)
        return square_wave(11)(k)

    res = WavefrontScheduler(space).run(ev)
    assert res.k_optimal == 11
    assert len(calls) == len(set(calls))


def test_wavefront_max_wave_chunks_and_agrees():
    space = make_space((2, 30), 0.7)
    capped = WavefrontScheduler(space, max_wave=2)
    res = capped.run(square_wave(19))
    assert res.k_optimal == 19
    assert all(len(w.ks) <= 2 for w in capped.waves)


def test_api_batched_executor_matches_threads():
    for k0 in (4, 13, 28):
        rb = binary_bleed_search(square_wave(k0), (2, 30), 0.7, executor="batched")
        rt = binary_bleed_search(square_wave(k0), (2, 30), 0.7, num_resources=4, executor="threads")
        assert rb.k_optimal == rt.k_optimal == k0


def test_scalar_plane_forwards_abort_only_when_accepted():
    seen = []

    def with_abort(k, should_abort=None):
        seen.append(should_abort)
        return 1.0

    plane = ScalarEvalPlane(with_abort)
    assert plane.accepts_abort
    plane.evaluate_one(3, should_abort=lambda: False)
    assert callable(seen[-1])
    plain = ScalarEvalPlane(lambda k: 0.5)
    assert not plain.accepts_abort
    assert plain.evaluate_batch([1, 2]) == [0.5, 0.5]


def test_as_eval_plane_accepts_batch_only_objects():
    class BatchOnly:
        def evaluate_batch(self, ks):
            return [float(k) for k in ks]

    plane = as_eval_plane(BatchOnly())
    assert plane.evaluate_one(7) == 7.0
    assert plane.evaluate_batch([1, 2]) == [1.0, 2.0]
    with pytest.raises(TypeError):
        as_eval_plane(42)


# ---------------------------------------------------------------------------
# (b) mask-padded batched fits vs their per-k counterparts
# ---------------------------------------------------------------------------
def test_kmeans_batched_matches_per_k():
    x, _ = blob_data(jax.random.fold_in(KEY, 1), n=120, d=5, k_true=4)
    ks = [2, 3, 4, 6, 7]
    batch = kmeans_batched(x, ks, KEY, k_pad=8, max_iters=50)
    for i, k in enumerate(ks):
        ref = kmeans(x, k, jax.random.fold_in(KEY, k), max_iters=50)
        assert bool(jnp.all(batch.labels[i] == ref.labels))
        np.testing.assert_allclose(
            np.asarray(batch.centroids[i][:k]), np.asarray(ref.centroids), rtol=1e-5, atol=1e-5
        )
        # padded centroid slots stay zero
        assert float(jnp.max(jnp.abs(batch.centroids[i][k:]))) == 0.0
        np.testing.assert_allclose(float(batch.inertia[i]), float(ref.inertia), rtol=1e-5)


def test_nmf_batched_matches_per_k():
    v, _, _ = nmf_data(jax.random.fold_in(KEY, 2), n=48, m=56, k_true=4)
    ks = [2, 3, 5, 6]
    k_pad = 8
    batch = nmf_batched(v, ks, KEY, k_pad=k_pad, iters=80)
    for i, k in enumerate(ks):
        sub = jax.random.fold_in(KEY, k)
        w0, h0 = nmf_init(sub, v.shape[0], v.shape[1], k, jnp.mean(v), v.dtype, k_pad=k_pad)
        ref = nmf(v, k, sub, iters=80, w0=w0, h0=h0)
        np.testing.assert_allclose(
            np.asarray(batch.w[i][:, :k]), np.asarray(ref.w), rtol=1e-4, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(batch.h[i][:k, :]), np.asarray(ref.h), rtol=1e-4, atol=1e-5
        )
        # masked components stay exactly zero
        assert float(jnp.max(jnp.abs(batch.w[i][:, k:]))) == 0.0
        np.testing.assert_allclose(float(batch.rel_error[i]), float(ref.rel_error), rtol=1e-5)


def test_nmfk_batched_matches_scalar_at_k_pad():
    """The docstring contract: at k == k_pad the scalar and batched NMFk
    scores coincide (same perturbation and init draws)."""
    from repro.factorization.nmfk import nmfk_score, nmfk_score_batched

    v, _, _ = nmf_data(jax.random.fold_in(KEY, 11), n=32, m=36, k_true=3)
    k = 4
    batch = nmfk_score_batched(v, [k], KEY, k_pad=k, n_perturbs=3, nmf_iters=40)
    ref = nmfk_score(v, k, jax.random.fold_in(KEY, k), n_perturbs=3, nmf_iters=40)
    np.testing.assert_allclose(float(batch.min_silhouette[0]), float(ref.min_silhouette), atol=1e-5)
    np.testing.assert_allclose(float(batch.mean_silhouette[0]), float(ref.mean_silhouette), atol=1e-5)
    np.testing.assert_allclose(float(batch.rel_error[0]), float(ref.rel_error), rtol=1e-5)


def test_plane_dispatch_cap_bounds_batch_padding():
    """WavefrontScheduler(max_wave=N) must keep plane batches within N."""
    from repro.factorization.planes import _BatchPlaneBase

    class Plane(_BatchPlaneBase):
        def __init__(self):
            super().__init__(k_pad=16, pad_batch=True)

        def evaluate_batch(self, ks):
            padded, _, n_real = self._pad_ks(ks)
            return [1.0 if k <= 9 else 0.0 for k in padded[:n_real]]

    plane = Plane()
    sched = WavefrontScheduler(make_space((2, 16), 0.7), max_wave=3)
    res = sched.run(plane)
    assert res.k_optimal == 9
    assert plane.dispatch_cap == 3
    assert all(b <= 3 for b, _ in plane.shapes_compiled)


def test_batched_fit_rejects_bad_k_pad():
    x, _ = blob_data(KEY, n=40, d=3, k_true=3)
    with pytest.raises(ValueError):
        kmeans_batched(x, [2, 6], KEY, k_pad=4)
    v, _, _ = nmf_data(KEY, n=24, m=28, k_true=3)
    with pytest.raises(ValueError):
        nmf_batched(v, [9], KEY, k_pad=4)


# ---------------------------------------------------------------------------
# masked scoring ignores padded clusters / points
# ---------------------------------------------------------------------------
def test_masked_scores_reduce_to_unmasked():
    pts = jax.random.normal(jax.random.fold_in(KEY, 3), (60, 4))
    lab = jax.random.randint(jax.random.fold_in(KEY, 4), (60,), 0, 5)
    s_ref = float(silhouette_score(pts, lab, 5))
    assert abs(float(silhouette_score_masked(pts, lab, 5)) - s_ref) < 1e-6
    # extra (empty) padded cluster slots change nothing
    assert abs(float(silhouette_score_masked(pts, lab, 9)) - s_ref) < 1e-6
    d_ref = float(davies_bouldin_score(pts, lab, 5))
    got = float(davies_bouldin_score_masked(pts, lab, 9, cluster_mask=jnp.arange(9) < 5))
    assert abs(got - d_ref) < 1e-5


def test_masked_silhouette_ignores_padding_points():
    pts = jax.random.normal(jax.random.fold_in(KEY, 5), (50, 4))
    lab = jax.random.randint(jax.random.fold_in(KEY, 6), (50,), 0, 4)
    s_ref = float(silhouette_score(pts, lab, 4))
    pts_p = jnp.concatenate([pts, jnp.zeros((14, 4))])
    lab_p = jnp.concatenate([lab, jnp.zeros((14,), lab.dtype)])
    got = float(silhouette_score_masked(pts_p, lab_p, 4, point_mask=jnp.arange(64) < 50))
    assert abs(got - s_ref) < 1e-5


def test_masked_scores_support_leading_batch_axis():
    pts = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 40, 3))
    lab = jax.random.randint(jax.random.fold_in(KEY, 8), (2, 40), 0, 4)
    s = silhouette_score_masked(pts, lab, 4)
    assert s.shape == (2,)
    for i in range(2):
        assert abs(float(s[i]) - float(silhouette_score(pts[i], lab[i], 4))) < 1e-6


# ---------------------------------------------------------------------------
# batched pairwise kernel entry point
# ---------------------------------------------------------------------------
def test_batched_pairwise_kernel_matches_oracle():
    from repro.core.scoring import pairwise_sq_dists
    from repro.kernels import ops

    x = jax.random.normal(jax.random.fold_in(KEY, 9), (3, 40, 7))
    y = jax.random.normal(jax.random.fold_in(KEY, 10), (3, 24, 7))
    got = ops.pairwise_sq_dists_batched(x, y)
    want = jax.vmap(lambda a, b: pairwise_sq_dists(a, b))(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)
    # scoring-layer 3-D dispatch routes through the same kernel
    got2 = pairwise_sq_dists(x, y, use_kernel=True)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want), rtol=3e-5, atol=3e-5)
