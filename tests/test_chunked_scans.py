"""Chunk-parallel WKV + chunk-unrolled selective scan vs naive recurrences
(§Perf iterations — these carry the biggest roofline wins, so they get
dedicated parity sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba as M
from repro.models import rwkv as R

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("l,chunk", [(64, 16), (128, 32), (48, 16)])
@pytest.mark.parametrize("decay_scale", [0.002, 0.3, 1.0])
def test_wkv_chunked_matches_naive(l, chunk, decay_scale):
    b, nh, hs = 2, 4, 16
    ks = jax.random.split(KEY, 5)
    rh = jax.random.normal(ks[0], (b, l, nh, hs))
    kh = jax.random.normal(ks[1], (b, l, nh, hs))
    vh = jax.random.normal(ks[2], (b, l, nh, hs))
    u = 0.1 * jax.random.normal(ks[3], (nh, hs))
    s0 = 0.1 * jax.random.normal(ks[4], (b, nh, hs, hs))
    wh = jnp.exp(-decay_scale * jax.random.uniform(ks[3], (b, l, nh, hs)))
    s_n, o_n = R._wkv_naive(rh, kh, vh, wh, u, s0)
    s_c, o_c = R._wkv_chunked(rh, kh, vh, wh, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_n), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_n), rtol=2e-4, atol=2e-4)


def test_wkv_chunked_gradients_match():
    b, l, nh, hs = 1, 32, 2, 8
    ks = jax.random.split(KEY, 4)
    rh = jax.random.normal(ks[0], (b, l, nh, hs))
    kh = jax.random.normal(ks[1], (b, l, nh, hs))
    vh = jax.random.normal(ks[2], (b, l, nh, hs))
    wh = jnp.exp(-0.1 * jax.random.uniform(ks[3], (b, l, nh, hs)))
    u = jnp.zeros((nh, hs))
    s0 = jnp.zeros((b, nh, hs, hs))

    def loss(fn, k):
        _, o = fn(rh, k, vh, wh, u, s0)
        return jnp.sum(o ** 2)

    g_n = jax.grad(lambda k: loss(R._wkv_naive, k))(kh)
    g_c = jax.grad(lambda k: loss(lambda *a: R._wkv_chunked(*a, chunk=16), k))(kh)
    np.testing.assert_allclose(np.asarray(g_c), np.asarray(g_n), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("l", [64, 63])  # chunked path and fallback path
def test_ssm_scan_chunked_matches_naive(l):
    b, d, n = 2, 24, 8
    ks = jax.random.split(KEY, 6)
    xs = jax.random.normal(ks[0], (b, l, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, d)))
    bb = jax.random.normal(ks[2], (b, l, n))
    cc = jax.random.normal(ks[3], (b, l, n))
    a = -jnp.exp(0.3 * jax.random.normal(ks[4], (d, n)))
    h0 = 0.1 * jax.random.normal(ks[5], (b, d, n))
    h1, y1 = M._ssm_scan(xs, dt, bb, cc, a, h0)
    # force the naive token path for reference
    old = M._SSM_CHUNK
    M._SSM_CHUNK = 1
    try:
        h2, y2 = M._ssm_scan(xs, dt, bb, cc, a, h0)
    finally:
        M._SSM_CHUNK = old
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)


def test_rwkv_time_mix_chunked_flag_consistent():
    from repro.configs import reduced_config, registry

    cfg = reduced_config(registry()["rwkv6-1.6b"])
    params = R.rwkv_time_mix_init(KEY, cfg, jnp.float32)
    from repro.models.layers import Axes

    ax = Axes(model_size=1)
    x = 0.1 * jax.random.normal(KEY, (2, 64, cfg.d_model), jnp.float32)
    y1 = R.rwkv_time_mix(params, x, cfg, ax, chunked=True)
    y2 = R.rwkv_time_mix(params, x, cfg, ax, chunked=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
