"""NMF / NMFk / K-Means / RESCAL substrates + distributed parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scoring import davies_bouldin_score, silhouette_score
from repro.factorization import (
    blob_data,
    distributed_nmf,
    distributed_rescal,
    kmeans,
    make_local_mesh,
    nmf,
    nmf_chunked,
    nmf_data,
    nmfk_score,
    rescal,
    rescal_data,
    rescalk_score,
)

KEY = jax.random.PRNGKey(0)


def test_nmf_monotone_convergence():
    v, _, _ = nmf_data(KEY, n=60, m=66, k_true=4)
    errs = [float(nmf(v, 4, KEY, iters=it).rel_error) for it in (10, 50, 150)]
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 0.05


def test_nmf_factors_nonnegative():
    v, _, _ = nmf_data(KEY, n=40, m=44, k_true=3)
    res = nmf(v, 3, KEY, iters=60)
    assert float(jnp.min(res.w)) >= 0.0 and float(jnp.min(res.h)) >= 0.0


def test_nmf_chunked_abort():
    v, _, _ = nmf_data(KEY, n=40, m=44, k_true=3)
    calls = []

    def should_abort():
        calls.append(1)
        return len(calls) >= 3  # abort after 2 chunks

    res = nmf_chunked(v, 3, KEY, iters=200, chunk=20, should_abort=should_abort)
    assert int(res.iters) == 40  # stopped early (§III-D)


def test_nmf_chunked_tol_stops_early():
    v, _, _ = nmf_data(KEY, n=40, m=44, k_true=3)
    res = nmf_chunked(v, 3, KEY, iters=500, chunk=25, tol=1e-5)
    assert int(res.iters) < 500


def test_kmeans_recovers_separated_blobs():
    x, labels_true = blob_data(KEY, n=300, d=4, k_true=4, std=0.3, spread=8.0)
    res = kmeans(x, 4, KEY)
    # cluster-purity via best-match: every true cluster maps to one found one
    purity = 0
    for c in range(4):
        members = np.asarray(res.labels)[np.asarray(labels_true) == c]
        purity += np.bincount(members, minlength=4).max()
    assert purity / len(x.tolist() if hasattr(x, 'tolist') else x) > 0.95


def test_kmeans_inertia_decreases_with_k():
    x, _ = blob_data(KEY, n=200, d=4, k_true=4, spread=6.0)
    i2 = float(kmeans(x, 2, KEY).inertia)
    i6 = float(kmeans(x, 6, KEY).inertia)
    assert i6 < i2


def test_nmfk_square_wave_at_k_true():
    """The paper's core assumption: silhouette high through k_true, cliff after."""
    v, _, _ = nmf_data(KEY, n=80, m=88, k_true=4)
    scores = {
        k: float(nmfk_score(v, k, jax.random.fold_in(KEY, k), n_perturbs=4, nmf_iters=100).min_silhouette)
        for k in (2, 3, 4, 5, 6)
    }
    assert scores[4] > 0.9
    assert scores[5] < 0.5 and scores[6] < 0.5
    assert scores[2] < scores[4] + 1e-6


def test_rescal_convergence():
    x, _, _ = rescal_data(KEY, n_entities=40, n_relations=3, k_true=3)
    res = rescal(x, 3, KEY, iters=120)
    assert float(res.rel_error) < 0.08


def test_rescalk_scores_stable_at_k_true():
    x, _, _ = rescal_data(KEY, n_entities=48, n_relations=3, k_true=4)
    s_true, _ = rescalk_score(x, 4, KEY, n_perturbs=4, iters=100)
    s_over, _ = rescalk_score(x, 7, KEY, n_perturbs=4, iters=100)
    assert float(s_true) > float(s_over)


def test_distributed_nmf_matches_quality():
    v, _, _ = nmf_data(KEY, n=64, m=72, k_true=3)
    mesh = make_local_mesh()
    dist = distributed_nmf(v, 3, KEY, mesh, iters=150)
    serial = nmf(v, 3, KEY, iters=150)
    assert float(dist.rel_error) < 0.05
    assert abs(float(dist.rel_error) - float(serial.rel_error)) < 0.05
    # W reconstructs V with H
    recon = dist.w @ dist.h
    rel = float(jnp.linalg.norm(v - recon) / jnp.linalg.norm(v))
    assert abs(rel - float(dist.rel_error)) < 1e-4


def test_distributed_rescal_quality():
    x, _, _ = rescal_data(KEY, n_entities=40, n_relations=3, k_true=3)
    mesh = make_local_mesh()
    res = distributed_rescal(x, 3, KEY, mesh, iters=100)
    assert float(res.rel_error) < 0.1


def test_scores_prefer_k_true_on_blobs():
    x, _ = blob_data(KEY, n=240, d=5, k_true=4, std=0.4, spread=8.0)
    sil, db = {}, {}
    for k in (2, 4, 8):
        res = kmeans(x, k, KEY)
        sil[k] = float(silhouette_score(x, res.labels, k))
        db[k] = float(davies_bouldin_score(x, res.labels, k))
    assert sil[4] == max(sil.values())
    assert db[4] == min(db.values())
