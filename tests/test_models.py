"""Per-arch smoke tests (reduced configs) + decode/prefill consistency.

Every assigned architecture instantiates a REDUCED config of the same
family and runs one forward/train step on CPU asserting output shapes and
finiteness; decode must match the full forward teacher-forced."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, reduced_config
from repro.models.layers import lm_logits
from repro.models.transformer import Model, build_segments

KEY = jax.random.PRNGKey(7)
ARCHS = sorted(registry())
B, L = 2, 24


def _model_and_batch(name, align_cf=False):
    cfg = reduced_config(registry()[name])
    if align_cf and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=2.0)
        )
    m = Model(cfg, remat="none", dtype=jnp.float32)
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (B, L + 4), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :L], "labels": tokens[:, 1 : L + 1]}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = 0.1 * jax.random.normal(KEY, (B, L, cfg.d_model), jnp.float32)
    return cfg, m, params, tokens, batch


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step(name):
    cfg, m, params, _, batch = _model_and_batch(name)
    loss, grads = jax.value_and_grad(m.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_output_shapes(name):
    cfg, m, params, _, batch = _model_and_batch(name)
    x = m.embed_input(params, batch)
    h, aux = m.backbone(params, x)
    assert h.shape == (B, L, cfg.d_model)
    logits = lm_logits(params["embed"], h, m.ax)
    assert logits.shape == (B, L, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_full_forward(name):
    cfg, m, params, tokens, batch = _model_and_batch(name, align_cf=True)

    def full_logits(n):
        bb = {"tokens": tokens[:, :n]}
        if cfg.input_mode == "embeddings":
            bb["embeds"] = 0.1 * jax.random.normal(KEY, (B, n, cfg.d_model), jnp.float32)
        x = m.embed_input(params, bb)
        h, _ = m.backbone(params, x)
        return lm_logits(params["embed"], h, m.ax)

    lg_pre, caches = m.prefill(params, batch, cache_len=L + 4)
    np.testing.assert_allclose(
        np.asarray(lg_pre[:, -1]), np.asarray(full_logits(L)[:, -1]), rtol=2e-3, atol=2e-3
    )
    if cfg.input_mode == "embeddings":
        return  # mixed-modality teacher forcing is not defined for the stub
    for i in range(2):
        tok = tokens[:, L + i : L + i + 1]
        lg, caches = m.decode_step(params, caches, tok, jnp.asarray(L + i, jnp.int32))
        want = full_logits(L + i + 1)[:, -1]
        np.testing.assert_allclose(np.asarray(lg[:, -1]), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_segments_cover_all_layers():
    for name, cfg in registry().items():
        segs = build_segments(cfg)
        assert sum(s.repeat * len(s.layers) for s in segs) == cfg.num_layers, name


def test_deepseek_first_layer_dense():
    segs = build_segments(registry()["deepseek-v2-236b"])
    assert segs[0].repeat == 1 and segs[0].layers[0].ffn == "dense"
    assert segs[1].repeat == 59 and segs[1].layers[0].ffn == "moe"


def test_jamba_pattern():
    segs = build_segments(registry()["jamba-v0.1-52b"])
    assert segs[0].repeat == 4 and len(segs[0].layers) == 8
    kinds = [l.mixer for l in segs[0].layers]
    assert kinds == ["m", "m", "m", "m", "a", "m", "m", "m"]
    assert [l.ffn == "moe" for l in segs[0].layers] == [False, True] * 4


def test_param_counts_match_published():
    reg = registry()
    assert abs(reg["deepseek-v2-236b"].param_count() / 236e9 - 1) < 0.02
    assert abs(reg["llama3-405b"].param_count() / 405e9 - 1) < 0.01
    assert abs(reg["jamba-v0.1-52b"].param_count() / 52e9 - 1) < 0.02
    assert abs(reg["deepseek-v2-236b"].active_param_count() / 21e9 - 1) < 0.05


def test_window_ring_cache_smaller_than_seq():
    cfg = reduced_config(registry()["h2o-danube-1.8b"])
    m = Model(cfg, remat="none", dtype=jnp.float32)
    caches = jax.eval_shape(lambda: m.cache_init(2, 1000))
    leaf = jax.tree.leaves(caches)[0]
    assert leaf.shape[2] == cfg.window  # ring-buffered, not 1000


def test_sliding_window_masks_old_tokens():
    """Token outside the window must not influence attention output."""
    from repro.models.attention import _sdpa

    k = jax.random.normal(KEY, (1, 8, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 8, 2, 16))
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 8, 2, 16))
    out1 = _sdpa(q, k, v, causal=True, window=3)
    k2 = k.at[:, 0].set(99.0)  # mutate a token > window away from the tail
    v2 = v.at[:, 0].set(99.0)
    out2 = _sdpa(q, k2, v2, causal=True, window=3)
    np.testing.assert_allclose(np.asarray(out1[:, -1]), np.asarray(out2[:, -1]), atol=1e-5)
