"""Fault tolerance + elasticity + straggler policy + restartable search."""
import math

from repro.core import FileCoordinator, ThreadPoolScheduler, make_space
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.runtime.straggler import SpeculationPolicy


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_failure_and_redistributes():
    clock = FakeClock()
    mon = HeartbeatMonitor({0: [1, 5, 9], 1: [3, 7, 11]}, timeout=10, clock=clock)
    clock.t = 5.0
    mon.beat(1)
    clock.t = 12.0  # resource 0 silent past timeout
    dead = mon.check()
    assert dead == [0]
    assert mon.remaining() == {1, 3, 5, 7, 9, 11}
    assert mon.resources[1].worklist and not mon.resources[0].worklist


def test_in_flight_work_requeued_on_failure():
    clock = FakeClock()
    mon = HeartbeatMonitor({0: [1, 5], 1: [3, 7]}, timeout=10, clock=clock)
    mon.mark_in_flight(0, 9)
    mon.fail(0)
    assert 9 in mon.remaining()  # idempotent re-queue


def test_check_on_already_failed_rid_is_stable():
    """A rid that already failed must not be re-reported by check(), must
    ignore late beats, and must not trigger another redistribution."""
    clock = FakeClock()
    mon = HeartbeatMonitor({0: [1, 5], 1: [3, 7]}, timeout=10, clock=clock)
    mon.fail(0)
    worklists_after_fail = {r.rid: list(r.worklist) for r in mon.resources.values()}
    clock.t = 100.0  # both silent past timeout, but 0 is already dead
    mon.beat(1)
    mon.beat(0)  # late beat from a dead resource: ignored
    assert mon.resources[0].last_beat == 0.0
    dead = mon.check()
    assert dead == []  # 0 not re-reported, 1 beat in time
    assert {r.rid: list(r.worklist) for r in mon.resources.values()} == worklists_after_fail
    mon.fail(0)  # explicit double-fail is also a no-op
    assert {r.rid: list(r.worklist) for r in mon.resources.values()} == worklists_after_fail


def test_heartbeat_age_gauge_and_failure_events():
    from repro.obs import Metrics, Tracer, use_metrics, use_tracer

    clock = FakeClock()
    tr, m = Tracer(), Metrics()
    with use_tracer(tr), use_metrics(m):
        mon = HeartbeatMonitor({0: [1, 5], 1: [3, 7]}, timeout=10, clock=clock)
        clock.t = 4.0
        mon.beat(1)
        clock.t = 6.0
        mon.check()
        assert m.gauge("heartbeat_age_max") == 6.0  # resource 0 never beat
        clock.t = 20.0
        mon.beat(1)  # keep 1 alive; only the silent resource 0 should die
        dead = mon.check()
    assert dead == [0]
    assert m.counter("failures") == 1
    fails = [e for e in tr.events() if e["name"] == "resource_failed"]
    assert len(fails) == 1 and fails[0]["args"]["rid"] == 0


def test_elastic_join_rebalances():
    clock = FakeClock()
    mon = HeartbeatMonitor({0: list(range(1, 13))}, timeout=10, clock=clock)
    rid = mon.join()
    assert rid == 1
    sizes = [len(r.worklist) for r in mon.resources.values() if r.alive]
    assert max(sizes) - min(sizes) <= 1


def test_speculation_policy():
    p = SpeculationPolicy(factor=1.5, min_samples=3)
    assert not p.should_speculate(5, elapsed=100.0)  # not enough samples
    for d in (1.0, 1.2, 0.9):
        p.observe_completion(1, d)
    assert p.should_speculate(5, elapsed=2.0)
    assert not p.should_speculate(5, elapsed=1.0)
    p.note_duplicate(5)
    assert not p.should_speculate(5, elapsed=9.0)  # max_duplicates reached


def test_speculation_median_edge_cases():
    # exactly min_samples completions flips the policy on
    p = SpeculationPolicy(factor=2.0, min_samples=2)
    p.observe_completion(1, 1.0)
    assert not p.should_speculate(9, elapsed=100.0)  # 1 < min_samples
    p.observe_completion(2, 3.0)
    # even count: statistics.median interpolates -> (1+3)/2 = 2
    assert not p.should_speculate(9, elapsed=4.0)  # 4 == factor*median: not >
    assert p.should_speculate(9, elapsed=4.0 + 1e-9)
    # a tail-heavy history moves the median, not the mean
    for d in (3.0, 3.0, 3.0):
        p.observe_completion(3, d)
    assert not p.should_speculate(9, elapsed=5.9)  # median now 3 -> cutoff 6
    assert p.should_speculate(9, elapsed=6.1)


def test_speculation_duplicate_accounting_per_k():
    p = SpeculationPolicy(factor=1.0, min_samples=1, max_duplicates=2)
    p.observe_completion(1, 1.0)
    p.note_duplicate(5)
    assert p.duplicates(5) == 1 and p.duplicates(7) == 0
    assert p.should_speculate(5, elapsed=9.0)  # 1 < max_duplicates=2
    p.note_duplicate(5)
    assert p.duplicates(5) == 2
    assert not p.should_speculate(5, elapsed=9.0)  # k=5 exhausted...
    assert p.should_speculate(7, elapsed=9.0)  # ...but k=7 unaffected


def test_speculation_emits_metrics_and_events():
    from repro.obs import Metrics, Tracer, use_metrics, use_tracer

    p = SpeculationPolicy(min_samples=1)
    tr, m = Tracer(), Metrics()
    with use_tracer(tr), use_metrics(m):
        p.note_duplicate(11)
    assert m.counter("speculations") == 1
    (ev,) = [e for e in tr.events() if e["name"] == "speculate"]
    assert ev["args"] == {"k": 11, "duplicates": 1}


def test_search_restart_resumes_exactly(tmp_path):
    """Kill the search after partial progress; restart must not re-evaluate
    journaled k and must still land on the right answer."""
    space = make_space((2, 30), 0.7)
    ev_calls: list[int] = []

    def evaluate(k, should_abort=None):
        ev_calls.append(k)
        return 1.0 if k <= 24 else 0.0

    coord1 = FileCoordinator(str(tmp_path))
    # phase 1: visit a couple of k manually (simulated partial run, then crash)
    for k in (16, 24):
        s = evaluate(k)
        coord1.record_visit(k, s, 0)
    # phase 2: restart
    coord2 = FileCoordinator(str(tmp_path))
    bounds, visited = coord2.replay(space.selects, space.stops)
    assert visited == {16, 24}
    assert bounds.k_optimal == 24
    ev_calls.clear()
    sched = ThreadPoolScheduler(space, 2, coordinator=coord2)
    res = sched.run(evaluate, skip=visited)
    assert res.k_optimal == 24
    assert 16 not in ev_calls and 24 not in ev_calls  # no re-evaluation
    assert all(k > 24 for k in ev_calls)  # lower ks pruned by replayed bounds


def test_failure_mid_search_then_rebalance_finds_k(tmp_path):
    """Integration: monitor + scheduler semantics under failure."""
    clock = FakeClock()
    from repro.core.chunking import plan_worklists

    wls = {i: wl for i, wl in enumerate(plan_worklists(range(2, 31), 3, "pre", "T4"))}
    mon = HeartbeatMonitor(wls, timeout=5, clock=clock)
    mon.fail(2)
    remaining = mon.remaining()
    space = make_space(sorted(remaining), 0.7)
    res = ThreadPoolScheduler(space, 2).run(lambda k: 1.0 if k <= 24 else 0.0)
    assert res.k_optimal == 24
