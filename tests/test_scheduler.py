"""Algorithms 3/4: multi-resource scheduling, pruning broadcast, elasticity,
straggler speculation, §III-D in-flight aborts."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ResourceEvent, SimulatedScheduler, ThreadPoolScheduler, make_space
from repro.core.scheduler import ScheduleTrace


def square_wave(k0):
    return lambda k: 1.0 if k <= k0 else 0.0


@given(k0=st.integers(2, 30), r=st.integers(1, 8), order=st.sampled_from(["pre", "post"]))
@settings(max_examples=80, deadline=None)
def test_simulated_finds_k0(k0, r, order):
    space = make_space((2, 30), 0.7)
    trace = SimulatedScheduler(space, r, order=order).run(square_wave(k0))
    assert trace.k_optimal == k0


@given(k0=st.integers(2, 30), r=st.integers(2, 8))
@settings(max_examples=60, deadline=None)
def test_threadpool_finds_k0(k0, r):
    space = make_space((2, 30), 0.7)
    res = ThreadPoolScheduler(space, r).run(square_wave(k0))
    assert res.k_optimal == k0


def test_parallel_visits_at_most_all():
    space = make_space((2, 100), 0.7)
    trace = SimulatedScheduler(space, 4).run(square_wave(70))
    assert trace.n_visited <= 99
    assert trace.n_visited + len(trace.skipped) == 99


def test_makespan_improves_with_resources():
    space = make_space((2, 60), 0.7)
    t1 = SimulatedScheduler(space, 1).run(square_wave(40))
    t4 = SimulatedScheduler(space, 4).run(square_wave(40))
    assert t4.makespan < t1.makespan


def test_paper_fig4_dynamics():
    """Fig 4 scenario: thresholds crossed at {7, 8, 10, 24}; k_opt = 24 and
    k values below the first crossing get pruned."""
    crossings = {7, 8, 10, 24}
    ev = lambda k: 1.0 if k in crossings else 0.0
    space = make_space((2, 30), 0.7)
    trace = SimulatedScheduler(space, 4, order="pre").run(ev)
    assert trace.k_optimal == 24


def test_abort_in_flight():
    """§III-D: long fits poll prune state between chunks and exit early.

    Two resources start their chunk midpoints (22 and 21); lower k runs
    longer, so 22 finishes first, selects, and prunes 21 mid-flight."""
    space = make_space((2, 40), 0.7)
    dur = lambda k: 41.0 - k
    sched = SimulatedScheduler(space, 2, duration_fn=dur, abort_in_flight=True)
    trace = sched.run(square_wave(39))
    assert trace.aborted, "expected in-flight aborts"
    assert trace.k_optimal == 39
    # aborted evaluations saved wall-clock vs letting them finish
    no_abort = SimulatedScheduler(space, 2, duration_fn=dur).run(square_wave(39))
    assert trace.busy_time < no_abort.busy_time


def test_straggler_speculation():
    space = make_space((2, 9), 0.7)
    dur = {k: 1.0 for k in space.ks}
    dur[3] = 50.0  # straggler
    sched = SimulatedScheduler(
        space, 4, duration_fn=lambda k: dur[k], speculate_stragglers=True
    )
    trace = sched.run(square_wave(9))
    assert trace.k_optimal == 9
    # speculation must not lose correctness and should not inflate visits
    assert trace.n_visited <= len(space.ks)


def test_resource_failure_rebalances():
    space = make_space((2, 40), 0.7)
    events = [ResourceEvent(t=1.5, kind="fail", rid=0)]
    trace = SimulatedScheduler(space, 4, duration_fn=lambda k: 1.0, events=events).run(
        square_wave(33)
    )
    assert trace.k_optimal == 33  # dead resource's work was re-dealt


def test_elastic_join_helps():
    # never-selecting scores: no pruning, so extra resources cut makespan
    space = make_space((2, 60), 0.99)
    ev = lambda k: 0.0
    base = SimulatedScheduler(space, 2, duration_fn=lambda k: 1.0).run(ev)
    events = [ResourceEvent(t=0.5, kind="join", rid=-1), ResourceEvent(t=0.5, kind="join", rid=-1)]
    grown = SimulatedScheduler(space, 2, duration_fn=lambda k: 1.0, events=events).run(ev)
    assert grown.n_visited == base.n_visited == 59
    assert grown.makespan < base.makespan


def test_busy_time_accounting():
    space = make_space((2, 20), 0.7)
    trace = SimulatedScheduler(space, 3, duration_fn=lambda k: 2.0).run(square_wave(15))
    assert math.isclose(trace.busy_time, 2.0 * trace.n_visited, rel_tol=1e-6)


def test_threadpool_abort_callback_wired():
    space = make_space((2, 16), 0.7)
    saw_abort_arg = []

    def ev(k, should_abort=None):
        saw_abort_arg.append(should_abort is not None)
        return 1.0 if k <= 9 else 0.0

    res = ThreadPoolScheduler(space, 2).run(ev)
    assert res.k_optimal == 9
    assert all(saw_abort_arg)


def test_threadpool_worker_exception_propagates():
    space = make_space((2, 8), 0.7)

    def ev(k):
        raise RuntimeError("fit crashed")

    with pytest.raises(RuntimeError):
        ThreadPoolScheduler(space, 2).run(ev)


def test_trace_to_result_roundtrip():
    space = make_space((2, 30), 0.7)
    trace = SimulatedScheduler(space, 3).run(square_wave(20))
    res = trace.to_result()
    assert res.k_optimal == 20
    assert res.n_visited == len(trace.visits)
