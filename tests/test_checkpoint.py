"""Checkpointing: atomicity, restore, async, k-search journal composition."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ck

KEY = jax.random.PRNGKey(0)


def _tree():
    return {
        "a": jax.random.normal(KEY, (8, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.ones((3,), jnp.bfloat16)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 5, t)
    got, step = ck.restore(str(tmp_path), t)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_step_ignores_incomplete(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 2, t)
    # corrupt step 3: directory without manifest (simulated mid-save kill)
    os.makedirs(tmp_path / "step_00000003")
    assert ck.latest_step(str(tmp_path)) == 2


def test_restore_rejects_shape_mismatch(tmp_path):
    ck.save(str(tmp_path), 1, {"a": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), {"a": jnp.ones((5,))})


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path / "nope"), {"a": jnp.ones(1)})


def test_prune_old_keeps_latest(tmp_path):
    t = {"a": jnp.ones((2,))}
    for s in range(6):
        ck.save(str(tmp_path), s, t)
    ck.prune_old(str(tmp_path), keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    remaining = sorted(p for p in os.listdir(tmp_path) if p.startswith("step_"))
    assert len(remaining) == 2


def test_async_checkpointer(tmp_path):
    saver = ck.AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3):
        saver.submit(s, t)
    saver.close()
    assert ck.latest_step(str(tmp_path)) == 3


def test_manifest_contents(tmp_path):
    t = {"a": jnp.ones((4, 2), jnp.float32)}
    d = ck.save(str(tmp_path), 7, t)
    man = json.load(open(os.path.join(d, "manifest.json")))
    assert man["step"] == 7
    assert man["leaves"][0]["shape"] == [4, 2]


def test_train_resume_continuity(tmp_path):
    """Kill-and-restart training: resumed run continues from the checkpoint."""
    from repro.launch.train import main

    a = main(["--arch", "qwen2-0.5b", "--steps", "6", "--batch", "4", "--seq", "16",
              "--ckpt", str(tmp_path), "--ckpt-every", "3", "--quiet"])
    assert ck.latest_step(str(tmp_path)) == 6
    b = main(["--arch", "qwen2-0.5b", "--steps", "10", "--batch", "4", "--seq", "16",
              "--ckpt", str(tmp_path), "--resume", "--quiet"])
    # resumed run trains only steps 6..9 and keeps improving
    assert len(b["losses"]) == 4
    assert b["losses"][-1] < a["losses"][0]
