"""Dry-run tooling: HLO parser on fixtures + real compiled programs;
input_specs coverage; mesh/axes helpers; data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, registry, shape_applicable
from repro.data.pipeline import DataConfig, SyntheticTokenSource
from repro.launch.mesh import apply_fsdp, make_axes

FIXTURE = """\
HloModule test, entry_computation_layout={()->f32[8,8]{1,0}}

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%g1), channel_id=1, to_apply=%add
  %dot.1 = f32[8,8]{1,0} dot(%g1, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%g0, %dot.1)
}

%cond.1 (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main () -> f32[8,8] {
  %init = (s32[], f32[8,8]) tuple()
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[16,8]{1,0} all-gather(%w), dimensions={0}
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_hlo_fixture():
    from repro.launch.dryrun import parse_hlo

    c = parse_hlo(FIXTURE)
    # all-reduce: 8*8*4 bytes * 12 trips (from cond constant)
    assert c["by_op"]["all-reduce"]["bytes"] == 8 * 8 * 4 * 12
    assert c["by_op"]["all-gather"]["bytes"] == 16 * 8 * 4
    # dot: 2*8*8*8 flops * 12 trips
    assert c["dot_flops_per_device"] == 2 * 8 * 8 * 8 * 12


def test_parse_hlo_real_program():
    from repro.launch.dryrun import parse_hlo

    def f(x, w):
        def body(c, _):
            return c @ w, ()

        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32), jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ).compile()
    c = parse_hlo(compiled.as_text())
    want = 2 * 16 * 16 * 16 * 7
    assert abs(c["dot_flops_per_device"] - want) / want < 0.01


def test_input_specs_cover_every_cell():
    from repro.launch.dryrun import input_specs

    for name, arch in registry().items():
        for sname, shape in SHAPES.items():
            ok, _ = shape_applicable(arch, shape)
            if not ok:
                continue
            ins = input_specs(arch, shape)
            assert "tokens" in ins
            assert ins["tokens"].shape[0] == shape.global_batch
            if arch.input_mode == "embeddings" and shape.kind != "decode":
                assert ins["embeds"].shape == (shape.global_batch, shape.seq_len, arch.d_model)


def test_make_axes_drops_unshardable_batch():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ax = make_axes(mesh, global_batch=1)
    assert ax.b is not None  # batch 1 shards over 1 device fine
    # simulated bigger mesh: batch 1 over dp 16 must replicate
    from repro.models.layers import Axes

    ax2 = Axes(batch=(), model="model", model_size=16)
    assert ax2.b is None


def test_apply_fsdp_widens_large_leaves():
    specs = {"big": P(None, "model"), "small": P(None, None), "stacked": P(None, None, "model")}
    shapes = {
        "big": jax.ShapeDtypeStruct((4096, 4096), jnp.bfloat16),
        "small": jax.ShapeDtypeStruct((64, 64), jnp.bfloat16),
        "stacked": jax.ShapeDtypeStruct((24, 4096, 4096), jnp.bfloat16),
    }
    out = apply_fsdp(specs, shapes, fsdp_axis="data", fsdp_size=16, min_elems=1 << 20)
    assert out["big"] == P("data", "model")
    assert out["small"] == P(None, None)  # too small
    assert out["stacked"] == P(None, "data", "model")  # never the stack dim


def test_pipeline_deterministic_and_shifted():
    arch = registry()["qwen2-0.5b"]
    shape = SHAPES["train_4k"]
    src = SyntheticTokenSource(arch, shape, DataConfig(seed=1))
    a = src.batch_at(3)
    b = src.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # restart-exact
    c = src.batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    assert a["tokens"].max() < arch.vocab_size


def test_long_500k_applicability():
    reg = registry()
    runs = {n for n in reg if shape_applicable(reg[n], SHAPES["long_500k"])[0]}
    assert runs == {"h2o-danube-1.8b", "jamba-v0.1-52b", "rwkv6-1.6b"}
