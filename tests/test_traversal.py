"""Traversal sorts (paper Fig. 1 / Table II) — exact values + properties."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.traversal import inverse_visit_rank, traversal_sort

KS_1_11 = list(range(1, 12))


def test_table2_preorder_exact():
    assert traversal_sort(KS_1_11, "pre") == [6, 3, 2, 1, 5, 4, 9, 8, 7, 11, 10]


def test_table2_postorder_exact():
    assert traversal_sort(KS_1_11, "post") == [1, 2, 4, 5, 3, 7, 8, 10, 11, 9, 6]


def test_table2_inorder_exact():
    assert traversal_sort(KS_1_11, "in") == KS_1_11


@pytest.mark.parametrize("order", ["pre", "in", "post"])
@given(ks=st.lists(st.integers(0, 10_000), min_size=0, max_size=200, unique=True))
@settings(max_examples=60, deadline=None)
def test_traversal_is_permutation(order, ks):
    ks = sorted(ks)
    out = traversal_sort(ks, order)
    assert sorted(out) == ks
    assert len(out) == len(ks)


@given(n=st.integers(1, 300))
@settings(max_examples=40, deadline=None)
def test_preorder_root_is_binary_search_midpoint(n):
    ks = list(range(n))
    out = traversal_sort(ks, "pre")
    assert out[0] == ks[n // 2]  # Algorithm 1's first probe


def test_inverse_visit_rank():
    ranks = inverse_visit_rank(KS_1_11, "pre")
    assert ranks[6] == 0 and ranks[3] == 1 and ranks[10] == 10


def test_bad_order_raises():
    with pytest.raises(ValueError):
        traversal_sort([1, 2], "bfs")
