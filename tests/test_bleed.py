"""Algorithm 1 (serial Binary Bleed) — correctness + paper invariants."""
import math

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Mode,
    binary_bleed_recursive,
    binary_bleed_worklist,
    make_space,
    standard_search,
)


def square_wave(k0, hi=1.0, lo=0.0):
    return lambda k: hi if k <= k0 else lo


def laplacian(k0, width=2.0):
    return lambda k: math.exp(-abs(k - k0) / width)


# ---------------------------------------------------------------------------
# exact-answer properties (paper: Binary Bleed preserves correct k)
# ---------------------------------------------------------------------------
@given(k0=st.integers(2, 30), kmax=st.integers(2, 30))
@settings(max_examples=100, deadline=None)
def test_squarewave_finds_k0_worklist(k0, kmax):
    if k0 > kmax:
        k0 = kmax
    space = make_space((2, kmax), 0.7)
    res = binary_bleed_worklist(space, square_wave(k0), order="pre")
    assert res.k_optimal == k0


@given(k0=st.integers(2, 30), kmax=st.integers(2, 30))
@settings(max_examples=100, deadline=None)
def test_squarewave_finds_k0_recursive(k0, kmax):
    if k0 > kmax:
        k0 = kmax
    space = make_space((2, kmax), 0.7)
    res = binary_bleed_recursive(space, square_wave(k0))
    assert res.k_optimal == k0


@given(k0=st.integers(2, 60), kmax=st.integers(10, 60), order=st.sampled_from(["pre", "post", "in"]))
@settings(max_examples=100, deadline=None)
def test_never_more_visits_than_linear(k0, kmax, order):
    """§III-D: 'Binary Bleed will not visit more k values than a linear search'."""
    space = make_space((2, kmax), 0.7)
    res = binary_bleed_worklist(space, square_wave(min(k0, kmax)), order=order)
    assert res.n_visited <= len(space.ks)


@given(k0=st.integers(5, 50))
@settings(max_examples=50, deadline=None)
def test_each_k_visited_at_most_once(k0):
    calls = []
    space = make_space((2, 60), 0.7)

    def ev(k):
        calls.append(k)
        return square_wave(k0)(k)

    binary_bleed_worklist(space, ev)
    assert len(calls) == len(set(calls))


def test_prunes_vs_standard():
    space = make_space((2, 30), 0.7)
    bb = binary_bleed_worklist(space, square_wave(24), order="pre")
    std = standard_search(space, square_wave(24))
    assert std.n_visited == 29  # standard visits 100% (paper)
    assert bb.n_visited < std.n_visited
    assert bb.k_optimal == std.k_optimal == 24


def test_early_stop_prunes_upper():
    space = make_space((2, 30), 0.7, stop_threshold=0.2)
    res = binary_bleed_worklist(space, square_wave(8), order="pre")
    assert res.k_optimal == 8
    # vanilla on the same problem visits more
    res_v = binary_bleed_worklist(make_space((2, 30), 0.7), square_wave(8), order="pre")
    assert res.n_visited <= res_v.n_visited


@given(k0=st.integers(2, 30))
@settings(max_examples=60, deadline=None)
def test_minimization_mode(k0):
    """Davies-Bouldin style: low score good, k_opt = max selecting k."""
    space = make_space((2, 30), 0.5, stop_threshold=1.5, mode=Mode.MINIMIZE)
    ev = lambda k: 0.1 if k <= k0 else 2.0
    res = binary_bleed_worklist(space, ev)
    assert res.k_optimal == k0


def test_laplacian_worst_case_degrades_gracefully():
    """§III-D worst case: peak distribution — may visit everything but must
    never exceed linear, and finds k0 if the peak is visited."""
    space = make_space((2, 30), 0.9)
    res = binary_bleed_worklist(space, laplacian(16, width=0.5), order="pre")
    assert res.n_visited <= 29
    assert res.k_optimal == 16  # 16 is the midpoint of [2..30] -> visited first


def test_no_crossing_returns_none():
    space = make_space((2, 20), 0.9)
    res = binary_bleed_worklist(space, lambda k: 0.0)
    assert res.k_optimal is None
    assert res.best_effort_k() is not None


def test_in_order_equals_linear_scan_for_vanilla():
    space = make_space((2, 30), 0.7)
    res = binary_bleed_worklist(space, square_wave(24), order="in")
    # ascending order: every k <= 24 selects (each is the new max); ks > 24
    # fail but were not yet pruned -> visits everything, like Standard
    assert res.n_visited == 29


@given(data=st.data())
@settings(max_examples=60, deadline=None)
def test_pruned_ks_cannot_change_answer(data):
    """Soundness of pruning: re-running with the skipped ks evaluated anyway
    (standard search) gives the same k_optimal under square-wave scores."""
    k0 = data.draw(st.integers(2, 40))
    kmax = data.draw(st.integers(k0, 45))
    space = make_space((2, kmax), 0.6)
    ev = square_wave(k0)
    assert (
        binary_bleed_worklist(space, ev).k_optimal
        == standard_search(space, ev).k_optimal
    )
