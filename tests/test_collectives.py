"""Ring collectives + pipelined MU schedule: properties and regressions.

All tests run on the 1-device runtime: ``jax.vmap`` with an ``axis_name``
gives the collectives (psum, psum_scatter, ppermute, all_gather,
axis_index) real semantics over the mapped axis, so shard-count behaviour
is testable without forcing extra XLA devices. Property tests use
hypothesis (the conftest stub degrades them to seeded sampling when the
real package is absent).
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.factorization import distributed
from repro.factorization.distributed import (
    _CHECK_KWARG,
    _dnmf_masked_local,
    _mu_sweeps,
    _resolve_unreplicated_kwarg,
    distributed_nmf,
    overlap_model,
    ring_psum,
    shard_map,
)


def _over_shards(fn, x_sharded):
    """Run ``fn(x_local)`` on every shard of axis 0 under a named axis."""
    return jax.vmap(fn, axis_name="s")(x_sharded)


# ---------------------------------------------------------------------------
# property: ring psum_scatter + gather == lax.psum
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    lead=st.integers(min_value=1, max_value=17),
    cols=st.integers(min_value=1, max_value=9),
    p=st.sampled_from([1, 2, 3, 4, 8]),
    dtype=st.sampled_from(["float32", "int32"]),
    ppermute=st.sampled_from([False, True]),
)
def test_ring_psum_matches_lax_psum(lead, cols, p, dtype, ppermute):
    # lead is drawn freely so non-multiples of p exercise the pad/trim path
    rng = np.random.default_rng(1_000_003 * lead + 1_009 * cols + 7 * p + ppermute)
    if dtype == "int32":
        x = rng.integers(-9, 9, size=(p, lead, cols)).astype(np.int32)
    else:
        x = rng.standard_normal((p, lead, cols)).astype(np.float32)

    got = _over_shards(lambda xl: ring_psum(xl, "s", p, use_ppermute=ppermute), x)
    ref = _over_shards(lambda xl: jax.lax.psum(xl, "s"), x)

    assert got.shape == ref.shape == x.shape
    if dtype == "int32":
        np.testing.assert_array_equal(got, ref)
    else:
        # float reduction order may differ between the tree psum and the ring
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# property: pipelined (one-sweep-stale) fit stays close to the sync fit
# ---------------------------------------------------------------------------
def _masked_fit_err(v, k_eff, key, k_pad, iters, p, comm):
    n = v.shape[0]
    v_sh = v.reshape(p, n // p, v.shape[1])

    def local(v_l):
        _, err = _dnmf_masked_local(
            v_l, jnp.asarray(k_eff), key, k_pad, iters, "s", n, comm=comm
        )
        return err

    errs = _over_shards(local, v_sh)
    np.testing.assert_allclose(errs, errs[0], rtol=1e-6)  # err is replicated
    return float(errs[0])


@settings(max_examples=6, deadline=None)
@given(
    per=st.sampled_from([6, 8, 12]),  # rows per shard (keeps n divisible by p)
    m=st.sampled_from([12, 20, 28]),
    k=st.integers(min_value=2, max_value=4),
    pad=st.sampled_from([0, 2]),
    p=st.sampled_from([2, 4]),
    seed=st.integers(min_value=0, max_value=99),
)
def test_pipelined_fit_within_staleness_tolerance(per, m, k, pad, p, seed):
    key = jax.random.PRNGKey(seed)
    n = per * p
    w = jax.random.uniform(jax.random.fold_in(key, 1), (n, k))
    h = jax.random.uniform(jax.random.fold_in(key, 2), (k, m))
    v = w @ h

    k_pad = k + pad
    err_sync = _masked_fit_err(v, k, key, k_pad, 60, p, "sync")
    err_pipe = _masked_fit_err(v, k, key, k_pad, 60, p, "pipelined")
    assert np.isfinite(err_sync) and np.isfinite(err_pipe)
    # documented staleness bound (see tests/_conformance_child.py TOL_PIPE)
    assert abs(err_sync - err_pipe) < 5e-2, (err_sync, err_pipe)


def test_pipelined_single_shard_is_exactly_sync():
    """axis_size == 1 has nothing to overlap: the pipelined schedule must
    fall back to the sync sweeps bit-for-bit (same fori_loop program)."""
    key = jax.random.PRNGKey(3)
    v = jax.random.uniform(key, (12, 10))
    mesh = distributed.make_local_mesh(1)
    a = distributed_nmf(v, 3, key, mesh, iters=40, comm="sync")
    b = distributed_nmf(v, 3, key, mesh, iters=40, comm="pipelined")
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    np.testing.assert_array_equal(np.asarray(a.h), np.asarray(b.h))
    assert float(a.rel_error) == float(b.rel_error)


def test_mu_sweeps_rejects_unknown_comm():
    v = jnp.ones((4, 3))
    with pytest.raises(ValueError, match="comm"):
        _mu_sweeps(v, jnp.ones((4, 2)), jnp.ones((2, 3)), None, 5, "s", "async", 2)


# ---------------------------------------------------------------------------
# overlap model sanity
# ---------------------------------------------------------------------------
def test_overlap_model_degenerates_without_data_sharding():
    m = overlap_model(512, 128, 8, data=1)
    assert m["overlap_fraction"] == 0.0
    assert m["comm_fraction"] == 0.0
    assert m["speedup"] == 1.0


def test_overlap_model_bounds_and_speedup():
    for data in (2, 4, 8):
        for balance in (1.0, 8.0, 64.0):
            m = overlap_model(512, 128, 8, data=data, machine_balance=balance)
            assert 0.0 < m["overlap_fraction"] <= 1.0
            assert 0.0 < m["comm_fraction"] < 1.0
            assert 1.0 <= m["speedup"] <= 1.0 / (1.0 - m["comm_fraction"]) + 1e-9
    # compute-rich shapes fully hide the Gram ring
    assert overlap_model(4096, 512, 8, data=4)["overlap_fraction"] == 1.0


# ---------------------------------------------------------------------------
# regression: check_rep/check_vma spelling resolved once at import
# ---------------------------------------------------------------------------
def test_resolve_unreplicated_kwarg_pins_both_spellings():
    def old_api(f, mesh=None, in_specs=None, out_specs=None, check_rep=True):
        pass

    def new_api(f, mesh=None, in_specs=None, out_specs=None, check_vma=True):
        pass

    def opaque(f, **kwargs):
        pass

    def neither(f, mesh=None, in_specs=None, out_specs=None):
        pass

    assert _resolve_unreplicated_kwarg(old_api) == "check_rep"
    assert _resolve_unreplicated_kwarg(new_api) == "check_vma"
    assert _resolve_unreplicated_kwarg(opaque) == "check_vma"
    assert _resolve_unreplicated_kwarg(neither) == "check_rep"


def test_check_kwarg_matches_installed_jax():
    """The import-time resolution must agree with the live shard_map: the
    old per-call try/except probe is gone, so a wrong answer here would
    TypeError on every unreplicated dispatch."""
    params = inspect.signature(distributed._shard_map).parameters
    has_var_kw = any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )
    assert _CHECK_KWARG in params or has_var_kw


def test_shim_forwards_resolved_kwarg_once(monkeypatch):
    calls = []

    def fake(f, mesh=None, in_specs=None, out_specs=None, **kwargs):
        calls.append(kwargs)
        return f

    monkeypatch.setattr(distributed, "_shard_map", fake)
    shard_map(lambda x: x, mesh=None, in_specs=(), out_specs=())
    shard_map(lambda x: x, mesh=None, in_specs=(), out_specs=(), check_rep=False)
    assert calls[0] == {}  # replication check left on by default
    assert calls[1] == {_CHECK_KWARG: False}  # single resolved spelling


def test_shim_unreplicated_path_works_on_live_jax():
    """End-to-end: the resolved spelling is one the installed jax accepts."""
    mesh = distributed.make_local_mesh(1)
    from jax.sharding import PartitionSpec as P

    fn = shard_map(
        lambda x: jax.lax.psum(x, "data"), mesh,
        in_specs=(P(),), out_specs=P(), check_rep=False,
    )
    np.testing.assert_allclose(jax.jit(fn)(jnp.arange(4.0)), jnp.arange(4.0))
