"""Observability layer: tracer/metrics primitives, exports, and the
instrumented search paths (record/skip accounting == SearchResult)."""
import json
import math
import threading

import jax
import jax.numpy as jnp

from repro.core import (
    SimulatedScheduler,
    ThreadPoolScheduler,
    WavefrontScheduler,
    binary_bleed_recursive,
    binary_bleed_worklist,
    make_space,
)
from repro.obs import (
    NULL_TRACER,
    Metrics,
    NullTracer,
    Tracer,
    get_metrics,
    get_tracer,
    use_metrics,
    use_tracer,
)

SPACE = make_space((2, 30), 0.7, 0.2)


def square_wave(k, should_abort=None):
    return 1.0 if k <= 24 else (0.05 if k >= 28 else 0.5)


# -- tracer primitives ----------------------------------------------------------


def test_default_tracer_is_null_and_noop():
    assert isinstance(get_tracer(), NullTracer)
    assert not get_tracer().enabled
    # the disabled path hands out one shared span object — no buffering
    s1 = NULL_TRACER.span("fit", k=3)
    s2 = NULL_TRACER.span("score")
    assert s1 is s2
    with s1 as sp:
        sp.set(score=1.0)
    NULL_TRACER.event("skip", k=5)
    assert NULL_TRACER.events() == []


def test_span_records_duration_and_attrs():
    clock_t = [0.0]
    tr = Tracer(clock=lambda: clock_t[0])
    with tr.span("fit", track="resource-0", k=7) as sp:
        clock_t[0] = 0.5
        sp.set(score=0.9)
    (rec,) = tr.events()
    assert rec["name"] == "fit" and rec["ph"] == "X"
    assert rec["track"] == "resource-0"
    assert rec["dur"] == 0.5 * 1e6
    assert rec["args"] == {"k": 7, "score": 0.9}


def test_events_are_thread_safe():
    tr = Tracer()

    def emit(i):
        for j in range(100):
            tr.event("e", track=f"t{i}", j=j)

    threads = [threading.Thread(target=emit, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.events()) == 800


def test_use_tracer_restores_previous():
    tr = Tracer()
    before = get_tracer()
    with use_tracer(tr):
        assert get_tracer() is tr
    assert get_tracer() is before


def test_export_jsonl(tmp_path):
    tr = Tracer()
    tr.event("bound_merge", lo=-math.inf)  # non-finite must stay strict JSON
    with tr.span("fit", k=2):
        pass
    path = str(tmp_path / "t.jsonl")
    n = tr.export_jsonl(path)
    lines = [json.loads(line) for line in open(path)]
    assert n == len(lines) == 2
    assert lines[0]["args"]["lo"] == "-inf"


def test_export_perfetto_structure(tmp_path):
    tr = Tracer()
    with tr.span("fit", track="resource-0", k=2):
        pass
    tr.event("skip", track="resource-1", k=9, bound=math.inf)
    path = str(tmp_path / "t.json")
    tr.export_perfetto(path)
    doc = json.load(open(path))  # strict JSON: load must not need allow_nan
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} == {"resource-0", "resource-1"}
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans and all("dur" in e and "ts" in e and "tid" in e for e in spans)
    instants = [e for e in evs if e["ph"] == "i"]
    assert instants and instants[0]["args"]["bound"] == "inf"


# -- metrics primitives ---------------------------------------------------------


def test_metrics_counters_gauges_histograms():
    m = Metrics()
    m.inc("ks_visited")
    m.inc("ks_visited", 4)
    m.set_gauge("heartbeat_age_max", 2.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        m.observe("fit_seconds", v)
    assert m.counter("ks_visited") == 5
    assert m.gauge("heartbeat_age_max") == 2.5
    h = m.histogram("fit_seconds")
    assert h["count"] == 4 and h["sum"] == 10.0 and h["min"] == 1.0 and h["max"] == 4.0
    assert h["p50"] in (2.0, 3.0)


def test_metrics_summary_is_json_safe():
    m = Metrics()
    m.set_gauge("lo_bound", -math.inf)
    m.observe("x", math.inf)
    s = m.summary()
    json.dumps(s, allow_nan=False)  # raises if any non-finite leaked
    assert s["gauges"]["lo_bound"] is None


def test_metrics_summary_visit_fraction():
    m = Metrics()
    m.set_gauge("ks_candidates", 20)
    m.inc("ks_visited", 5)
    m.inc("ks_skipped", 15)
    s = m.summary()["search"]
    assert s["visit_fraction"] == 0.25 and s["saved_vs_grid"] == 0.75
    assert s["ks_candidates"] == 20


def test_use_metrics_restores_previous():
    m = Metrics()
    before = get_metrics()
    with use_metrics(m):
        get_metrics().inc("x")
    assert get_metrics() is before
    assert m.counter("x") == 1


# -- instrumented search paths --------------------------------------------------


def _accounting(driver):
    tr, m = Tracer(), Metrics()
    with use_tracer(tr), use_metrics(m):
        res = driver(SPACE, square_wave)
    s = m.summary()["search"]
    assert s["ks_visited"] + s["ks_skipped"] == len(SPACE.ks)
    assert s["visit_fraction"] == res.visit_fraction
    names = {e["name"] for e in tr.events()}
    assert "record" in names
    return res, s, names


def test_worklist_accounting_matches_result():
    res, s, names = _accounting(binary_bleed_worklist)
    assert res.k_optimal == 24
    assert s["ks_skipped"] > 0 and "skip" in names


def test_recursive_accounting_matches_result():
    res, s, names = _accounting(binary_bleed_recursive)
    assert res.k_optimal == 24
    assert "subtree_prune" in names or "skip" in names


def test_wavefront_spans_and_accounting():
    tr, m = Tracer(), Metrics()
    with use_tracer(tr), use_metrics(m):
        sched = WavefrontScheduler(SPACE)
        res = sched.run(square_wave)
    s = m.summary()["search"]
    assert s["ks_visited"] == res.n_visited
    assert s["ks_visited"] + s["ks_skipped"] == len(SPACE.ks)
    waves = [e for e in tr.events() if e["name"] == "wave"]
    assert len(waves) == sched.n_dispatches
    assert all(e["track"] == "wavefront" for e in waves)
    assert m.histogram("wave_size")["count"] == sched.n_dispatches
    pubs = [e for e in tr.events() if e["name"] == "publish"]
    assert len(pubs) == sched.n_dispatches


def test_threadpool_spans_and_metrics():
    tr, m = Tracer(), Metrics()
    with use_tracer(tr), use_metrics(m):
        res = ThreadPoolScheduler(SPACE, 3).run(square_wave)
    assert m.counter("ks_visited") == res.n_visited
    assert m.counter("publish_count") == res.n_visited
    fits = [e for e in tr.events() if e["name"] == "fit"]
    assert len(fits) == res.n_visited
    assert all(e["track"].startswith("resource-") for e in fits)
    assert all("score" in e["args"] for e in fits)
    assert m.histogram("fit_seconds")["count"] == res.n_visited
    assert m.histogram("publish_latency_s")["count"] == res.n_visited
    workers = [e for e in tr.events() if e["name"] == "worker"]
    assert len(workers) == 3


def test_abort_event_fires_when_evaluator_polls():
    """An evaluator that polls ``should_abort`` after its k was pruned must
    produce exactly one abort event + ks_aborted increment for that k."""
    space = make_space((2, 10), 0.7)
    tr, m = Tracer(), Metrics()

    seen = []

    def evaluate(k, should_abort=None):
        seen.append(k)
        if should_abort is not None:
            should_abort()  # poll once mid-"fit"
        return 1.0 if k <= 6 else 0.0

    with use_tracer(tr), use_metrics(m):
        ThreadPoolScheduler(space, 1).run(evaluate)
    # serial worklist through one worker: ks pruned mid-flight never happen
    # here, so aborts are zero — the counter exists but stays 0
    assert m.counter("ks_aborted") == 0

    # now simulate a pruned-in-flight k: the wrapper fires once per poll run
    tr2, m2 = Tracer(), Metrics()
    with use_tracer(tr2), use_metrics(m2):
        sched = ThreadPoolScheduler(space, 1)
        coord = sched.coordinator
        from repro.core import Bounds

        def eval_abort(k, should_abort=None):
            coord.publish(Bounds(float(k), math.inf, k))  # prune self mid-fit
            assert should_abort() is True
            should_abort()  # second poll must not double-count
            return 0.5

        sched.run(eval_abort)
    aborts = [e for e in tr2.events() if e["name"] == "abort"]
    assert m2.counter("ks_aborted") == len(aborts) > 0


def test_schedule_trace_converter(tmp_path):
    space = make_space((2, 30), 0.7)
    trace = SimulatedScheduler(space, 4).run(lambda k: 1.0 if k <= 24 else 0.0)
    tr = trace.to_tracer()
    spans = [e for e in tr.events() if e["ph"] == "X"]
    assert len(spans) == len(trace.visits) + len(trace.aborted)
    tracks = {e["track"] for e in spans}
    assert tracks <= {f"resource-{r}" for r in range(4)}
    # logical seconds -> microseconds
    by_end = max(spans, key=lambda e: e["ts"] + e["dur"])
    assert by_end["ts"] + by_end["dur"] == trace.makespan * 1e6
    path = str(tmp_path / "sim.json")
    n = trace.export_perfetto(path)
    doc = json.load(open(path))
    assert len(doc["traceEvents"]) == n


def test_plane_compile_events_and_spans():
    from repro.factorization.planes import KMeansBatchPlane

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 3))
    tr, m = Tracer(), Metrics()
    with use_tracer(tr), use_metrics(m):
        plane = KMeansBatchPlane(x, key, k_pad=6, max_iters=5)
        plane.evaluate_batch([2, 3])
        plane.evaluate_batch([4, 5])  # same padded shape — no new compile
        plane.evaluate_batch([2, 3, 4])  # new padded batch shape
    assert m.counter("compile_count") == len(plane.shapes_compiled) == 2
    compiles = [e for e in tr.events() if e["name"] == "compile"]
    assert len(compiles) == 2
    fits = [e for e in tr.events() if e["name"] == "fit"]
    scores = [e for e in tr.events() if e["name"] == "score"]
    assert len(fits) == len(scores) == 3
    assert all(e["track"] == "device:0" for e in fits + scores)


def test_ksearch_trace_and_metrics_files(tmp_path):
    """Live (non-simulated) batched run: Perfetto-loadable trace with
    fit/score/publish spans + metrics whose visit_fraction matches the
    SearchResult accounting — the PR's acceptance path, scaled down."""
    from repro.launch.ksearch import main

    tpath = str(tmp_path / "t.perfetto.json")
    mpath = str(tmp_path / "m.json")
    out = main([
        "--n", "48", "--m", "56", "--k-max", "8", "--k-true", "4",
        "--n-perturbs", "2", "--nmf-iters", "30",
        "--executor", "batched", "--quiet",
        "--trace", tpath, "--metrics", mpath,
    ])
    doc = json.load(open(tpath))
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"wave", "fit", "score", "publish", "record"} <= names
    mdoc = json.load(open(mpath))
    assert mdoc["summary"]["search"]["visit_fraction"] == mdoc["result"]["visit_fraction"]
    assert round(mdoc["result"]["visit_fraction"], 3) == out["visit_fraction"]
    assert mdoc["summary"]["search"]["ks_visited"] == out["n_visited"]
    assert mdoc["summary"]["search"]["compile_count"] >= 1
