"""Cross-executor conformance: scalar / batched / sharded / pipelined.

One subprocess child per forced device count (the
``--xla_force_host_platform_device_count`` flag must precede jax init, and
this pytest process already holds a 1-device runtime). The child —
``tests/_conformance_child.py`` — runs the full executor × model matrix
(NMFk + K-Means) on fixed seeds and asserts identical ``k_optimal`` plus
score agreement within the tolerances documented in its module docstring.

Device counts 1 and 4 run in tier-1; 2 and 8 carry ``slow`` (deselected by
the default ``-m "not slow"`` addopts, exercised by the CI slow job) so
the default suite pays for two childs, not four.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.multidevice


@pytest.mark.parametrize(
    "devices",
    [
        1,
        pytest.param(2, marks=pytest.mark.slow),
        4,
        pytest.param(8, marks=pytest.mark.slow),
    ],
)
def test_cross_executor_conformance(devices):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p
    )
    proc = subprocess.run(
        [
            sys.executable,
            os.path.join(REPO, "tests", "_conformance_child.py"),
            str(devices),
        ],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900,
    )
    assert proc.returncode == 0, f"child failed:\n{proc.stdout}\n{proc.stderr}"
    assert f"conformance child OK devices={devices}" in proc.stdout
